"""Tests: versioned snapshot store (multiversioning application) and the
wait-free writable big atomic (Algorithm 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import multiversion as mv
from repro.core import wf_writable as wf


def tiny_state(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((4,)), jnp.float32),
            "step": jnp.int32(0)}


# ---------------------------------------------------------------------------
# multiversion
# ---------------------------------------------------------------------------

def test_publish_snapshot_roundtrip():
    s0 = tiny_state()
    store = mv.init_store(s0, n_slots=3)
    s1 = jax.tree.map(lambda x: x + 1, s0)
    store = mv.publish(store, s1, step=1)
    snap = mv.snapshot_with_validation(store)
    assert int(snap.step) == 1
    np.testing.assert_array_equal(np.asarray(snap.state["w"]),
                                  np.asarray(s1["w"]))


def test_reader_never_sees_torn_state():
    """Writer frozen mid-copy: protocol readers return the OLD consistent
    state; the torn slot itself fails validation (negative control)."""
    s0 = tiny_state()
    store = mv.init_store(s0, n_slots=2)
    s1 = jax.tree.map(lambda x: x + 100.0, s0)
    store = mv.publish(store, s1, step=1)
    s2 = jax.tree.map(lambda x: x + 999.0, s1)
    torn = mv.begin_publish(store, s2)           # frozen mid-copy
    snap = mv.snapshot_with_validation(torn)
    np.testing.assert_array_equal(np.asarray(snap.state["w"]),
                                  np.asarray(s1["w"]))   # old state, not torn
    # the torn slot is detectably inconsistent
    bad_slot = (int(torn.head) + 1) % 2
    bad = mv.Snapshot(jax.tree.map(lambda b: b[bad_slot], torn.slots),
                      torn.step[bad_slot], jnp.int32(bad_slot),
                      torn.version[bad_slot])
    assert not bool(mv.validate(torn, bad))
    # and the torn slot REALLY is torn (half new, half old)
    w = np.asarray(torn.slots["w"])[bad_slot].reshape(-1)
    assert (w[:8] == np.asarray(s2["w"]).reshape(-1)[:8]).all()
    assert not (w[8:] == np.asarray(s2["w"]).reshape(-1)[8:]).all()


def test_publish_sequence_head_always_consistent():
    s = tiny_state()
    store = mv.init_store(s, n_slots=2)
    for i in range(1, 6):
        s = jax.tree.map(lambda x: x * 1.1 if x.dtype == jnp.float32 else x, s)
        store = mv.publish(store, s, step=i)
        snap = mv.snapshot_with_validation(store)
        assert int(snap.step) == i
        assert int(store.version[snap.slot]) % 2 == 0


# ---------------------------------------------------------------------------
# wf_writable (Algorithm 3)
# ---------------------------------------------------------------------------

def test_load_store_cas_basic():
    st_ = wf.init(n=4, k=2)
    st_ = wf.store(st_, 1, [7, 8])
    np.testing.assert_array_equal(np.asarray(wf.load(st_, jnp.asarray([1]))),
                                  [[7, 8]])
    st_, ok = wf.cas_batch(st_, jnp.asarray([1]), [[7, 8]], [[9, 10]])
    assert bool(ok[0])
    st_, ok = wf.cas_batch(st_, jnp.asarray([1]), [[7, 8]], [[0, 0]])
    assert not bool(ok[0])
    np.testing.assert_array_equal(np.asarray(wf.load(st_, jnp.asarray([1]))),
                                  [[9, 10]])


def test_pending_store_invisible_until_helped_then_transfers():
    """The descheduled-writer interleaving: begin_store installs in W; loads
    still see the old value (they linearize before the pending store); the
    next CAS helps first, so it sees the NEW value — exactly Algorithm 3."""
    st_ = wf.init(n=2, k=2)
    st_ = wf.store(st_, 0, [1, 1])
    st_ = wf.begin_store(st_, 0, [2, 2])         # writer stalls mid-store
    assert bool(wf.pending(st_)[0])
    np.testing.assert_array_equal(
        np.asarray(wf.load(st_, jnp.asarray([0]))), [[1, 1]])  # not yet
    # a CAS expecting the OLD value must FAIL (it helps the writer first)
    st_, ok = wf.cas_batch(st_, jnp.asarray([0]), [[1, 1]], [[3, 3]])
    assert not bool(ok[0])
    np.testing.assert_array_equal(
        np.asarray(wf.load(st_, jnp.asarray([0]))), [[2, 2]])  # transferred
    assert not bool(wf.pending(st_)[0])


def test_store_to_same_value_is_silent():
    st_ = wf.init(n=2, k=2)
    st_ = wf.store(st_, 0, [5, 5])
    seq0 = int(st_.z_seq[0])
    st_ = wf.begin_store(st_, 0, [5, 5])         # Line 17: early return
    assert not bool(wf.pending(st_)[0])
    assert int(st_.z_seq[0]) == seq0


def test_second_writer_linearizes_silently_before_pending():
    """With a pending write on the slot, a second begin_store does not even
    install (Line 18 branch): after help, the FIRST write is the value."""
    st_ = wf.init(n=2, k=2)
    st_ = wf.begin_store(st_, 0, [1, 1])
    st_ = wf.begin_store(st_, 0, [2, 2])         # silent
    st_ = wf.help_write(st_)
    np.testing.assert_array_equal(
        np.asarray(wf.load(st_, jnp.asarray([0]))), [[1, 1]])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), n=st.integers(1, 6),
       n_ops=st.integers(1, 24))
def test_wf_writable_linearizable_vs_oracle(seed, n, n_ops):
    """Random scripts of load/begin_store/help/cas/store on k=2 atomics are
    bit-identical to the sequential oracle with help-point semantics."""
    rng = np.random.default_rng(seed)
    st_ = wf.init(n=n, k=2, p_max=n_ops + 4)
    vals0 = np.asarray(st_.z_value)
    script = []
    outs = []
    for _ in range(n_ops):
        s = int(rng.integers(0, n))
        kind = rng.choice(["load", "begin_store", "store", "help", "cas"])
        if kind == "load":
            script.append(("load", s))
            outs.append(np.asarray(wf.load(st_, jnp.asarray([s])))[0])
        elif kind == "begin_store":
            v = rng.integers(0, 5, 2).astype(np.uint32)
            script.append(("begin_store", s, v))
            st_ = wf.begin_store(st_, s, v)
        elif kind == "store":
            v = rng.integers(0, 5, 2).astype(np.uint32)
            script.append(("store", s, v))
            st_ = wf.store(st_, s, v)
        elif kind == "help":
            script.append(("help",))
            st_ = wf.help_write(st_)
        else:
            e = rng.integers(0, 5, 2).astype(np.uint32)
            d = rng.integers(0, 5, 2).astype(np.uint32)
            script.append(("cas", s, e, d))
            st_, ok = wf.cas_batch(st_, jnp.asarray([s]), e[None], d[None])
            outs.append(bool(ok[0]))
    st_ = wf.help_write(st_)
    script.append(("help",))             # mirror the final transfer
    ref_vals, ref_outs = wf.oracle_apply(vals0, script)
    np.testing.assert_array_equal(np.asarray(st_.z_value), ref_vals)
    assert len(outs) == len(ref_outs)
    for a, b in zip(outs, ref_outs):
        if isinstance(b, bool):
            assert a == b
        else:
            np.testing.assert_array_equal(a, b)
