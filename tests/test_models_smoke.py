"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs; decode consistency vs prefill."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, Shape, applicable, reduced_shape
from repro.launch.specs import cache_specs, input_specs, materialize
from repro.launch.steps import (init_train_state, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models.transformer import forward, init_cache, init_params
from repro.optim import AdamWConfig


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch, reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])
    batch = materialize(input_specs(cfg, shape), vocab=cfg.vocab)
    params, opt_state = init_train_state(cfg, AdamWConfig(warmup=1,
                                                          total_steps=10))
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup=1, total_steps=10)))
    params2, opt2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), loss
    assert loss > 0
    # params actually moved
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases(arch):
    cfg = get_config(arch, reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])
    batch = materialize(input_specs(cfg, shape), vocab=cfg.vocab)
    opt_cfg = AdamWConfig(lr=5e-3, warmup=1, total_steps=50)
    params, opt_state = init_train_state(cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    for _ in range(8):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_consistency(arch):
    """Prefill T tokens, then decode token T given the cache: logits must
    match a full forward over T+1 tokens at position T."""
    cfg = get_config(arch, reduced=True)
    if not cfg.causal:
        pytest.skip("encoder-only: no decode step")
    # fp32 so prefill-vs-decode mismatch measures protocol bugs, not bf16
    # reduction-order noise.
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    B, T = 2, 64
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, T + 1)).astype(np.int32)
    params = init_params(cfg, jax.random.PRNGKey(1))

    def full_batch(t):
        b = {"tokens": jnp.asarray(toks[:, :t])}
        if cfg.family == "vlm":
            b["vision_embeds"] = jnp.zeros((B, 8, cfg.d_model), jnp.bfloat16)
            b["positions"] = jnp.broadcast_to(
                jnp.arange(t, dtype=jnp.int32)[None, :, None], (B, t, 3))
        return b

    logits_full, _, _ = forward(params, cfg, full_batch(T + 1), mode="train")
    # prefill T, decode position T (cache sized T+1 for headroom)
    prefill = make_prefill_step(cfg, max_len=T + 1)
    serve = make_serve_step(cfg)
    _, cache = prefill(params, full_batch(T))
    dec_batch = {"tokens": jnp.asarray(toks[:, T:T + 1]),
                 "pos": jnp.full((B,), T, jnp.int32)}
    logits_dec, new_cache = serve(params, cache, dec_batch)
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
    assert (np.argmax(a, -1) == np.argmax(b, -1)).all()


@pytest.mark.parametrize("arch", ["deepseek_7b", "mamba2_780m",
                                  "recurrentgemma_9b", "mixtral_8x7b"])
def test_multi_token_decode_matches_forward(arch):
    """Decode 4 tokens autoregressively == teacher-forced full forward."""
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    B, T, D = 2, 32, 4
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, (B, T + D)).astype(np.int32)
    params = init_params(cfg, jax.random.PRNGKey(3))
    logits_full, _, _ = forward(
        params, cfg, {"tokens": jnp.asarray(toks)}, mode="train")
    prefill = make_prefill_step(cfg, max_len=T + D)
    serve = jax.jit(make_serve_step(cfg))
    _, cache = prefill(params, {"tokens": jnp.asarray(toks[:, :T])})
    for d in range(D):
        batch = {"tokens": jnp.asarray(toks[:, T + d:T + d + 1]),
                 "pos": jnp.full((B,), T + d, jnp.int32)}
        logits_dec, cache = serve(params, cache, batch)
        a = np.asarray(logits_full[:, T + d], np.float32)
        b = np.asarray(logits_dec[:, 0], np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
        assert (np.argmax(a, -1) == np.argmax(b, -1)).all(), d


def test_encoder_has_no_decode_cells():
    cfg = get_config("hubert_xlarge")
    ok, why = applicable(cfg, SHAPES["decode_32k"])
    assert not ok and "encoder" in why


def test_long_context_skips_full_attention():
    for arch, expect in [("deepseek_7b", False), ("mamba2_780m", True),
                         ("recurrentgemma_9b", True), ("mixtral_8x7b", True),
                         ("glm4_9b", False), ("qwen2_vl_7b", False)]:
        cfg = get_config(arch)
        ok, why = applicable(cfg, SHAPES["long_500k"])
        assert ok == expect, (arch, why)
