"""System-level integration: the paper's primitive driving the framework's
substrates end to end (training w/ versioned snapshots, serving w/ the
CacheHash page table), plus cross-strategy equivalence of the whole stack."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import Shape
from repro.core import bigatomic as ba
from repro.core import semantics as sem


def test_all_strategies_agree_on_random_workloads():
    """Every strategy is the SAME linearizable object: identical results on
    identical op streams (layouts differ, semantics must not)."""
    rng = np.random.default_rng(0)
    n, k, p = 64, 4, 128
    tables = {s: ba.BigAtomicTable(n, k, s, p_max=p)
              for s in ["seqlock", "indirect", "cached_wf", "cached_me"]}
    for step in range(5):
        cur = np.asarray(tables["seqlock"].logical())
        ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=0.5,
                               zipf=0.8 if step % 2 else 0.0, current=cur)
        outs = {}
        for s, t in tables.items():
            res, stats, _ = t.apply(ops)
            outs[s] = (np.asarray(res.value), np.asarray(res.success),
                       np.asarray(t.logical()))
        base = outs["seqlock"]
        for s, o in outs.items():
            np.testing.assert_array_equal(o[0], base[0], err_msg=s)
            np.testing.assert_array_equal(o[1], base[1], err_msg=s)
            np.testing.assert_array_equal(o[2], base[2], err_msg=s)


def test_train_then_serve_roundtrip(tmp_path):
    """Train a tiny model with checkpointing, restore it, serve it through
    the paged engine: the loop every production system must close."""
    from repro.checkpoint import latest_step, restore_checkpoint
    from repro.launch.train import train
    from repro.launch.steps import init_train_state
    from repro.optim import AdamWConfig
    from repro.serving import Request, ServingEngine

    cfg = get_config("deepseek_7b", reduced=True)
    shape = Shape("train", 64, 2, "train")
    d = str(tmp_path)
    train(cfg, shape, steps=4, ckpt_dir=d, ckpt_every=2, log_every=0)
    step = latest_step(d)
    assert step == 4
    params0, opt0 = init_train_state(cfg, AdamWConfig(), 0)
    (params, _), meta = restore_checkpoint(d, step, (params0, opt0))
    assert meta["arch"] == cfg.name

    eng = ServingEngine(cfg, params, max_batch=2, n_pages=16, page_size=8,
                        max_pages_per_seq=4)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 10,
                                                  ).astype(np.int32),
                       max_new_tokens=3))
    out = eng.run_to_completion()
    assert len(out[0]) == 3
    assert all(0 <= t < cfg.vocab for t in out[0])


def test_versioned_store_reader_during_training():
    """An async reader snapshots mid-training and gets exactly the state of
    some completed step (never a blend of two steps)."""
    from repro.core import multiversion as mv
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig
    from repro.data import synthetic_batch

    cfg = get_config("deepseek_7b", reduced=True)
    shape = Shape("train", 64, 2, "train")
    opt_cfg = AdamWConfig(warmup=1, total_steps=8)
    params, opt = init_train_state(cfg, opt_cfg, 0)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    store = mv.init_store((params, opt), n_slots=2)
    states_by_step = {0: jax.tree.leaves(params)[0]}
    for step in range(4):
        batch = synthetic_batch(cfg, shape, seed=0, step=step)
        params, opt, _ = step_fn(params, opt, batch)
        store = mv.publish(store, (params, opt), step + 1)
        states_by_step[step + 1] = jax.tree.leaves(params)[0]
        snap = mv.snapshot_with_validation(store)
        got = jax.tree.leaves(snap.state[0])[0]
        np.testing.assert_array_equal(
            np.asarray(got, np.float32),
            np.asarray(states_by_step[int(snap.step)], np.float32))
