"""Fault-tolerance tests: atomic checkpoints, bit-identical preemption
resume, straggler watchdog logic, elastic resharding (subprocess with 8
placeholder devices), the oversubscribed multi-stream executor (DESIGN.md
§9), deterministic data pipeline."""

import json
import os
import signal
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, list_steps, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.configs.shapes import SHAPES, reduced_shape
from repro.data import DataPipeline, synthetic_batch
from repro.runtime import PreemptionGuard, StragglerWatchdog, mesh_plan
from repro.runtime.stragglers import StragglerPlan


# ---------------------------------------------------------------------------
# checkpoint atomicity
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    state = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": {"c": jnp.int32(7)}}
    d = str(tmp_path)
    save_checkpoint(d, 10, state, meta={"next_step": 10})
    # a fake interrupted write: staging dir with no manifest
    os.makedirs(os.path.join(d, ".staging_dead"), exist_ok=True)
    # and a torn final dir missing its manifest
    os.makedirs(os.path.join(d, "step_00000020"), exist_ok=True)
    assert list_steps(d) == [10]                 # torn ckpt invisible
    got, meta = restore_checkpoint(d, 10, state)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(state["a"]))
    assert int(got["b"]["c"]) == 7
    assert meta["next_step"] == 10


def test_preemption_guard_flag():
    with PreemptionGuard() as g:
        assert not g.should_stop
        g.request_stop()
        assert g.should_stop


def test_preemption_guard_restores_handlers_on_enter_failure():
    """A failed __enter__ (handler i raises) must roll back handlers
    0..i-1 — a guard that never activated may not leak signal handlers."""
    marker = lambda signum, frame: None          # noqa: E731
    old = signal.signal(signal.SIGTERM, marker)
    try:
        with pytest.raises((ValueError, OSError)):
            # 2nd entry is not a valid signal: installing it raises AFTER
            # SIGTERM's handler was already swapped
            with PreemptionGuard(signals=(signal.SIGTERM, 10 ** 6)):
                pytest.fail("enter must not succeed")
        assert signal.getsignal(signal.SIGTERM) is marker
        # and a clean enter/exit round-trips the handler too
        with PreemptionGuard(signals=(signal.SIGTERM,)):
            assert signal.getsignal(signal.SIGTERM) is not marker
        assert signal.getsignal(signal.SIGTERM) is marker
    finally:
        signal.signal(signal.SIGTERM, old)


def test_preempt_resume_bit_identical(tmp_path):
    """Train 8 steps straight vs 4 steps -> 'preempt' -> resume 4 more:
    final params must be bit-identical."""
    from repro.launch.train import train
    cfg = get_config("deepseek_7b", reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    p_full, _, _ = train(cfg, shape, steps=8, ckpt_dir=d1, ckpt_every=100,
                         log_every=0)

    class StopAt:
        def __init__(self, n):
            self.n = n
            self.seen = 0

        @property
        def should_stop(self):
            self.seen += 1
            return self.seen > self.n

    train(cfg, shape, steps=8, ckpt_dir=d2, ckpt_every=100, log_every=0,
          guard=StopAt(4))
    assert latest_step(d2) == 5          # preempted after finishing step 5
    p_res, _, _ = train(cfg, shape, steps=8, ckpt_dir=d2, ckpt_every=100,
                        log_every=0)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

def test_straggler_flags_after_patience():
    w = StragglerWatchdog(n_hosts=4, threshold=1.5, patience=3,
                          spares=["spare0"])
    for _ in range(2):
        plan = w.observe([1.0, 1.0, 1.0, 5.0])
        assert plan.flagged == []                # patience not reached
    plan = w.observe([1.0, 1.0, 1.0, 5.0])
    assert plan.flagged == [3]
    assert plan.swap == {3: "spare0"}
    assert plan.shrink == []
    # next flagged host has no spare left -> shrink plan
    w2 = StragglerWatchdog(n_hosts=2, patience=1)
    plan = w2.observe([1.0, 9.0])
    assert plan.shrink == [1]


def test_straggler_blip_does_not_flag():
    w = StragglerWatchdog(n_hosts=3, patience=2)
    w.observe([1.0, 1.0, 1.0])
    plan = w.observe([1.0, 1.0, 30.0])           # one-off blip
    assert plan.flagged == []
    plan = w.observe([1.0, 1.0, 1.0])
    assert plan.flagged == []                    # EWMA recovered


def test_mesh_plan_reports_dropped_devices():
    """Surviving-device counts that don't factorize are REPORTED, never
    silently truncated (a 7-survivor cluster quietly running on 4 devices
    is a capacity bug)."""
    assert mesh_plan(8, model_parallel=2) == (4, 2, 8, 0)
    assert mesh_plan(7, model_parallel=4) == (7, 1, 7, 0)
    p = mesh_plan(7, model_parallel=1, global_batch=4)
    assert (p.data, p.model, p.used, p.dropped) == (1, 1, 1, 6)
    p = mesh_plan(6, model_parallel=4, global_batch=4)
    assert (p.used, p.dropped) == (2, 4)
    assert mesh_plan(6, model_parallel=4).dropped == 0


# ---------------------------------------------------------------------------
# the oversubscribed multi-stream executor (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _synth_streams(n_streams, *, n, k, width, n_batches, seed0=50):
    from repro.runtime import SyntheticStream
    return [SyntheticStream(f"s{i}", seed=seed0 + i, n=n, k=k, width=width,
                            n_batches=n_batches, hot_cells=3, hot_frac=0.25)
            for i in range(n_streams)]


def test_executor_oversubscribed_local_matches_oracle():
    """3 streams, in-flight budget 4 on 1 slot: the journaled interleaving
    replays through ONE sequential oracle and the final table matches."""
    from repro import atomics
    from repro.core import engine
    from repro.runtime import Executor, LocalTarget
    sys.path.insert(0, os.path.dirname(__file__))
    from oracle import replay_executor_history

    n, k, width = 24, 2, 8
    rng = np.random.default_rng(0)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    target = LocalTarget(atomics.AtomicSpec(n, k, "seqlock", p_max=64), init)
    streams = _synth_streams(3, n=n, k=k, width=width, n_batches=5)
    ex = Executor(target, streams, slots=1, oversubscription=4)
    rep = ex.run()
    assert rep["issues"] == 15 and ex.budget == 4
    oracle = replay_executor_history(n, k, [width] * 3, ex.history,
                                     initial=init)
    np.testing.assert_array_equal(
        oracle.data, np.asarray(engine.logical(target.spec, target.state)))
    np.testing.assert_array_equal(oracle.version,
                                  np.asarray(target.state.version))


def test_executor_preempt_checkpoint_resume(tmp_path):
    """A preempt fault mid-run drains + checkpoints to disk; a FRESH
    executor (new process stand-in) resumes from it and finishes with the
    table bit-identical to an uninterrupted run."""
    from repro import atomics
    from repro.core import engine
    from repro.runtime import Executor, Fault, FaultInjector, LocalTarget

    n, k, width = 24, 2, 8
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=64)
    rng = np.random.default_rng(1)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)

    ref = LocalTarget(spec, init)
    Executor(ref, _synth_streams(2, n=n, k=k, width=width,
                                 n_batches=6)).run()
    want = np.asarray(engine.logical(spec, ref.state))

    d = str(tmp_path)
    t1 = LocalTarget(spec, init)
    ex1 = Executor(t1, _synth_streams(2, n=n, k=k, width=width, n_batches=6),
                   injector=FaultInjector([Fault(round=3, kind="preempt")]),
                   checkpoint_dir=d)
    rep1 = ex1.run()
    assert rep1["stopped"] and latest_step(d) is not None

    t2 = LocalTarget(spec, init)                 # fresh process stand-in
    ex2 = Executor(t2, _synth_streams(2, n=n, k=k, width=width, n_batches=6),
                   checkpoint_dir=d)
    ex2.resume()
    rep2 = ex2.run()
    assert not rep2["stopped"]
    np.testing.assert_array_equal(
        want, np.asarray(engine.logical(spec, t2.state)))
    np.testing.assert_array_equal(np.asarray(ref.state.version),
                                  np.asarray(t2.state.version))


def test_executor_watchdog_deprioritizes_delayed_stream():
    """An injected delay makes stream 1 a straggler; the watchdog flags it
    and the executor skips its next issue slot (work still completes)."""
    from repro import atomics
    from repro.runtime import (Executor, Fault, FaultInjector, LocalTarget,
                               StragglerWatchdog)

    n, k, width = 24, 2, 8
    target = LocalTarget(atomics.AtomicSpec(n, k, "seqlock", p_max=64))
    streams = _synth_streams(3, n=n, k=k, width=width, n_batches=8)
    ex = Executor(
        target, streams, slots=1, oversubscription=4,
        watchdog=StragglerWatchdog(n_hosts=3, threshold=1.5, patience=2),
        injector=FaultInjector([Fault(round=1, kind="delay", stream=1,
                                      seconds=0.05, rounds=4)]))
    rep = ex.run()
    assert rep["deprioritized"] > 0
    assert all(s.done() for s in streams)
    assert rep["faults_fired"] and rep["faults_fired"][0]["kind"] == "delay"


class _TickClock:
    """Deterministic stand-in for perf_counter: every call advances 1ms, so
    each issue measures exactly one tick and injected delays dominate."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.001
        return self.t


def test_straggler_flagged_after_exactly_patience_rounds():
    """The executor feeds the watchdog from the obs Recorder's per-stream
    issue latencies (`Recorder.latency_vector`): a stream degraded from
    round 1 is flagged at EXACTLY round `patience` — the first round its
    latency window is full — and the flag lands in `recorder.flags`.
    The Recorder's injectable clock makes the latencies exact (healthy
    streams 1ms, the faulted stream +50ms), so the round is deterministic."""
    from repro import atomics
    from repro.obs import Recorder
    from repro.runtime import (Executor, Fault, FaultInjector, LocalTarget,
                               StragglerWatchdog)

    patience = 3
    n, k, width = 24, 2, 8
    target = LocalTarget(atomics.AtomicSpec(n, k, "seqlock", p_max=64))
    streams = _synth_streams(4, n=n, k=k, width=width, n_batches=10)
    ex = Executor(
        target, streams, slots=1, oversubscription=4,
        watchdog=StragglerWatchdog(n_hosts=4, threshold=1.5,
                                   patience=patience),
        injector=FaultInjector([Fault(round=1, kind="delay", stream=2,
                                      seconds=0.05, rounds=10)]),
        recorder=Recorder(trace=False, clock=_TickClock()))
    ex.run()
    assert ex.recorder.flags, "degraded stream never flagged"
    first_round, flagged = ex.recorder.flags[0]
    assert flagged == [2]
    assert first_round == patience
    assert ex.recorder.metrics()["exec.straggler_flags"] >= 1


def test_mcas_stream_yields_between_rounds():
    """An MCAS batch advances one protocol round per scheduling slot,
    interleaving with a foreign ops stream on DISJOINT cells: the txns
    still all commit and the ops stream's history still replays."""
    from repro import atomics
    from repro.core import engine
    from repro.runtime import Executor, LocalTarget, McasStream
    sys.path.insert(0, os.path.dirname(__file__))
    from oracle import replay_executor_history

    n, k, width, t, w = 32, 2, 8, 4, 2
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=64)
    rng = np.random.default_rng(2)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    target = LocalTarget(spec, init)
    # txns on cells [0, 16), ops stream on [16, 32): disjoint footprints
    slots = rng.permutation(16)[: t * w].reshape(t, w).astype(np.int32)
    desired = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    txns = atomics.make_txns(slots, init[slots], desired, k=k)
    from repro.runtime import SyntheticStream
    ops_stream = SyntheticStream("ops", seed=9, n=n, k=k, width=width,
                                 n_batches=4, slot_lo=16, slot_hi=32)
    mc = McasStream("mcas", txns)
    ex = Executor(target, [ops_stream, mc], slots=1, oversubscription=2)
    ex.run()
    res = mc.result()
    assert np.asarray(res.success).all()
    got = np.asarray(engine.logical(spec, target.state))
    np.testing.assert_array_equal(got[slots.ravel()],
                                  desired.reshape(-1, k))
    oracle = replay_executor_history(n, k, [width], ex.history, initial=init)
    np.testing.assert_array_equal(oracle.data[16:], got[16:])


# ---------------------------------------------------------------------------
# elastic reshard (subprocess: 8 placeholder devices)
# ---------------------------------------------------------------------------

ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_config
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.runtime import elastic_mesh, reshard_state
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig
    from repro import dist

    cfg = get_config("deepseek_7b", reduced=True)
    opt_cfg = AdamWConfig(warmup=1, total_steps=4)
    params, opt = init_train_state(cfg, opt_cfg, 0)

    # save on a 4-device mesh
    mesh4 = elastic_mesh(4, model_parallel=2, global_batch=2)
    p4, o4 = reshard_state((params, opt), cfg, mesh4)
    save_checkpoint("{d}", 1, (p4, o4), meta={{"next_step": 1}})

    # restore + reshard onto an 8-device mesh, run one step
    mesh8 = elastic_mesh(8, model_parallel=4, global_batch=2)
    (p8, o8), _ = restore_checkpoint("{d}", 1, (params, opt))
    p8, o8 = reshard_state((p8, o8), cfg, mesh8)
    rules = dist.make_rules(cfg, mesh8)
    from repro.configs.shapes import SHAPES, reduced_shape
    from repro.data import synthetic_batch
    batch = synthetic_batch(cfg, reduced_shape(SHAPES["train_4k"]),
                            seed=0, step=0)
    with dist.axis_rules(mesh8, rules):
        import jax.numpy as jnp
        step = jax.jit(make_train_step(cfg, opt_cfg))
        p2, o2, m = step(p8, o8, jax.device_put(
            batch, dist.batch_shardings(batch, mesh8, rules)))
    assert np.isfinite(float(m["loss"]))
    # leaves on mesh8 really are distributed over 8 devices
    lead = jax.tree.leaves(p2)[1]
    assert len(lead.sharding.device_set) in (2, 4, 8), lead.sharding
    print("ELASTIC_OK", float(m["loss"]))
""")


def test_elastic_reshard_4_to_8_devices(tmp_path):
    script = ELASTIC_SCRIPT.format(d=str(tmp_path))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_pipeline_pure_function_of_step():
    cfg = get_config("deepseek_7b", reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])
    p = DataPipeline(cfg, shape, seed=3)
    b1 = p.batch(5)
    b2 = DataPipeline(cfg, shape, seed=3).batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p.batch(6)["tokens"], b1["tokens"])


def test_pipeline_host_sharding_assembles_global_batch():
    """4-host shards concatenate to exactly the 1-host global batch, so an
    elastic rescale does not perturb the data stream."""
    cfg = get_config("deepseek_7b", reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])._replace(global_batch=4) \
        if hasattr(reduced_shape(SHAPES["train_4k"]), "_replace") else None
    from repro.configs.shapes import Shape
    shape = Shape("train_4k", 64, 4, "train")
    whole = synthetic_batch(cfg, shape, seed=1, step=2)["tokens"]
    parts = [synthetic_batch(cfg, shape, seed=1, step=2, host_id=h,
                             n_hosts=4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_memmap_source(tmp_path):
    from repro.data import make_memmap_corpus
    cfg = get_config("deepseek_7b", reduced=True)
    from repro.configs.shapes import Shape
    shape = Shape("train_4k", 32, 2, "train")
    path = make_memmap_corpus(str(tmp_path / "corpus.bin"), 32 * 64,
                              cfg.vocab)
    p = DataPipeline(cfg, shape, seed=0, source="memmap", memmap_path=path)
    b = p.batch(0)
    assert b["tokens"].shape == (2, 32)
    assert (b["tokens"] < cfg.vocab).all()
    np.testing.assert_array_equal(
        b["tokens"],
        DataPipeline(cfg, shape, seed=0, source="memmap",
                     memmap_path=path).batch(0)["tokens"])
