"""Fault-tolerance tests: atomic checkpoints, bit-identical preemption
resume, straggler watchdog logic, elastic resharding (subprocess with 8
placeholder devices), deterministic data pipeline."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, list_steps, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.configs.shapes import SHAPES, reduced_shape
from repro.data import DataPipeline, synthetic_batch
from repro.runtime import PreemptionGuard, StragglerWatchdog
from repro.runtime.stragglers import StragglerPlan


# ---------------------------------------------------------------------------
# checkpoint atomicity
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    state = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": {"c": jnp.int32(7)}}
    d = str(tmp_path)
    save_checkpoint(d, 10, state, meta={"next_step": 10})
    # a fake interrupted write: staging dir with no manifest
    os.makedirs(os.path.join(d, ".staging_dead"), exist_ok=True)
    # and a torn final dir missing its manifest
    os.makedirs(os.path.join(d, "step_00000020"), exist_ok=True)
    assert list_steps(d) == [10]                 # torn ckpt invisible
    got, meta = restore_checkpoint(d, 10, state)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(state["a"]))
    assert int(got["b"]["c"]) == 7
    assert meta["next_step"] == 10


def test_preemption_guard_flag():
    with PreemptionGuard() as g:
        assert not g.should_stop
        g.request_stop()
        assert g.should_stop


def test_preempt_resume_bit_identical(tmp_path):
    """Train 8 steps straight vs 4 steps -> 'preempt' -> resume 4 more:
    final params must be bit-identical."""
    from repro.launch.train import train
    cfg = get_config("deepseek_7b", reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    p_full, _, _ = train(cfg, shape, steps=8, ckpt_dir=d1, ckpt_every=100,
                         log_every=0)

    class StopAt:
        def __init__(self, n):
            self.n = n
            self.seen = 0

        @property
        def should_stop(self):
            self.seen += 1
            return self.seen > self.n

    train(cfg, shape, steps=8, ckpt_dir=d2, ckpt_every=100, log_every=0,
          guard=StopAt(4))
    assert latest_step(d2) == 5          # preempted after finishing step 5
    p_res, _, _ = train(cfg, shape, steps=8, ckpt_dir=d2, ckpt_every=100,
                        log_every=0)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

def test_straggler_flags_after_patience():
    w = StragglerWatchdog(n_hosts=4, threshold=1.5, patience=3,
                          spares=["spare0"])
    for _ in range(2):
        plan = w.observe([1.0, 1.0, 1.0, 5.0])
        assert plan.flagged == []                # patience not reached
    plan = w.observe([1.0, 1.0, 1.0, 5.0])
    assert plan.flagged == [3]
    assert plan.swap == {3: "spare0"}
    assert plan.shrink == []
    # next flagged host has no spare left -> shrink plan
    w2 = StragglerWatchdog(n_hosts=2, patience=1)
    plan = w2.observe([1.0, 9.0])
    assert plan.shrink == [1]


def test_straggler_blip_does_not_flag():
    w = StragglerWatchdog(n_hosts=3, patience=2)
    w.observe([1.0, 1.0, 1.0])
    plan = w.observe([1.0, 1.0, 30.0])           # one-off blip
    assert plan.flagged == []
    plan = w.observe([1.0, 1.0, 1.0])
    assert plan.flagged == []                    # EWMA recovered


# ---------------------------------------------------------------------------
# elastic reshard (subprocess: 8 placeholder devices)
# ---------------------------------------------------------------------------

ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_config
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.runtime import elastic_mesh, reshard_state
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig
    from repro import dist

    cfg = get_config("deepseek_7b", reduced=True)
    opt_cfg = AdamWConfig(warmup=1, total_steps=4)
    params, opt = init_train_state(cfg, opt_cfg, 0)

    # save on a 4-device mesh
    mesh4 = elastic_mesh(4, model_parallel=2, global_batch=2)
    p4, o4 = reshard_state((params, opt), cfg, mesh4)
    save_checkpoint("{d}", 1, (p4, o4), meta={{"next_step": 1}})

    # restore + reshard onto an 8-device mesh, run one step
    mesh8 = elastic_mesh(8, model_parallel=4, global_batch=2)
    (p8, o8), _ = restore_checkpoint("{d}", 1, (params, opt))
    p8, o8 = reshard_state((p8, o8), cfg, mesh8)
    rules = dist.make_rules(cfg, mesh8)
    from repro.configs.shapes import SHAPES, reduced_shape
    from repro.data import synthetic_batch
    batch = synthetic_batch(cfg, reduced_shape(SHAPES["train_4k"]),
                            seed=0, step=0)
    with dist.axis_rules(mesh8, rules):
        import jax.numpy as jnp
        step = jax.jit(make_train_step(cfg, opt_cfg))
        p2, o2, m = step(p8, o8, jax.device_put(
            batch, dist.batch_shardings(batch, mesh8, rules)))
    assert np.isfinite(float(m["loss"]))
    # leaves on mesh8 really are distributed over 8 devices
    lead = jax.tree.leaves(p2)[1]
    assert len(lead.sharding.device_set) in (2, 4, 8), lead.sharding
    print("ELASTIC_OK", float(m["loss"]))
""")


def test_elastic_reshard_4_to_8_devices(tmp_path):
    script = ELASTIC_SCRIPT.format(d=str(tmp_path))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_pipeline_pure_function_of_step():
    cfg = get_config("deepseek_7b", reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])
    p = DataPipeline(cfg, shape, seed=3)
    b1 = p.batch(5)
    b2 = DataPipeline(cfg, shape, seed=3).batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p.batch(6)["tokens"], b1["tokens"])


def test_pipeline_host_sharding_assembles_global_batch():
    """4-host shards concatenate to exactly the 1-host global batch, so an
    elastic rescale does not perturb the data stream."""
    cfg = get_config("deepseek_7b", reduced=True)
    shape = reduced_shape(SHAPES["train_4k"])._replace(global_batch=4) \
        if hasattr(reduced_shape(SHAPES["train_4k"]), "_replace") else None
    from repro.configs.shapes import Shape
    shape = Shape("train_4k", 64, 4, "train")
    whole = synthetic_batch(cfg, shape, seed=1, step=2)["tokens"]
    parts = [synthetic_batch(cfg, shape, seed=1, step=2, host_id=h,
                             n_hosts=4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_memmap_source(tmp_path):
    from repro.data import make_memmap_corpus
    cfg = get_config("deepseek_7b", reduced=True)
    from repro.configs.shapes import Shape
    shape = Shape("train_4k", 32, 2, "train")
    path = make_memmap_corpus(str(tmp_path / "corpus.bin"), 32 * 64,
                              cfg.vocab)
    p = DataPipeline(cfg, shape, seed=0, source="memmap", memmap_path=path)
    b = p.batch(0)
    assert b["tokens"].shape == (2, 32)
    assert (b["tokens"] < cfg.vocab).all()
    np.testing.assert_array_equal(
        b["tokens"],
        DataPipeline(cfg, shape, seed=0, source="memmap",
                     memmap_path=path).batch(0)["tokens"])
