"""Pallas flash-attention kernel vs the pure-jnp pair-list oracle:
shape/dtype/mask sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_tpu
from repro.models.attention import flash_attention as flash_ref


def _mk(b, tq, tkv, h, kvh, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, tq, h, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, tkv, kvh, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, tkv, kvh, hd)), dtype)
    return q, k, v


CASES = [
    # b, t, h, kvh, hd, causal, window, qb, kvb
    (1, 64, 2, 2, 16, True, 0, 32, 32),
    (2, 128, 4, 2, 32, True, 0, 64, 64),
    (1, 96, 4, 1, 16, True, 0, 32, 32),       # ragged t, GQA g=4
    (2, 64, 2, 2, 16, False, 0, 32, 32),      # bidirectional (encoder)
    (1, 128, 4, 4, 16, True, 32, 32, 32),     # sliding window
    (1, 64, 8, 2, 64, True, 0, 64, 16),       # tall kv blocks
]


@pytest.mark.parametrize("b,t,h,kvh,hd,causal,window,qb,kvb", CASES)
def test_flash_kernel_matches_oracle(b, t, h, kvh, hd, causal, window,
                                     qb, kvb):
    q, k, v = _mk(b, t, t, h, kvh, hd, jnp.float32)
    got = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              q_block=qb, kv_block=kvb, interpret=True)
    want = flash_ref(q, k, v, causal=causal, window=window,
                     q_block=qb, kv_block=kvb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    q, k, v = _mk(2, 64, 64, 4, 2, 32, jnp.bfloat16)
    got = flash_attention_tpu(q, k, v, causal=True, q_block=32, kv_block=32,
                              interpret=True)
    want = flash_ref(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_kernel_matches_dense_softmax():
    """Direct check against an unblocked softmax attention."""
    b, t, h, hd = 1, 48, 2, 16
    q, k, v = _mk(b, t, t, h, h, hd, jnp.float32, seed=3)
    got = flash_attention_tpu(q, k, v, causal=True, q_block=16, kv_block=16,
                              interpret=True)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = np.tril(np.ones((t, t), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
