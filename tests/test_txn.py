"""repro.txn acceptance tests (ISSUE 4): k-word MCAS, bounded version
lists and the optimistic transactional map, property-tested against the
whole-transaction oracles (tests/oracle.py TxnOracle / MapOracle) over all
four lock-free strategies AND a test-registered plug-in strategy.  The
mesh-sharded variants run in tests/test_distributed.py (dist_checks.py
scenarios `mcas` / `txnmap`); this file is the single-device suite and
runs under the CI BIGATOMIC_STRATEGY matrix like the rest of tier-1."""

import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from oracle import MapOracle, TxnOracle, txn_batch
from repro import atomics
from repro.core import cachehash as ch
from repro.core.specs import VersionSpec
from repro.sync.queue import BackoffPolicy
from repro.txn import map as txn_map
from repro.txn import mcas as txn_mcas
from repro.txn import versionlist as vl

LOCKFREE = ["seqlock", "indirect", "cached_wf", "cached_me"]


# ---------------------------------------------------------------------------
# MCAS: property tests — width x contention x strategy vs the TxnOracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LOCKFREE)
def test_mcas_matches_txn_oracle(strategy):
    rng = np.random.default_rng(zlib.crc32(strategy.encode()))
    for w, n in ((1, 4), (2, 6), (4, 10)):      # txn width x contention
        k = int(rng.integers(1, 4))
        t = int(rng.integers(2, 9))
        spec = atomics.AtomicSpec(n, k, strategy, p_max=128)
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        state = atomics.init(spec, init)
        oracle = TxnOracle(n, k, initial=init)
        for step in range(4):
            txns = txn_batch(rng, t=t, w=w, n=n, k=k, current=oracle.data)
            state, res = atomics.mcas(spec, state, txns)
            oracle.step_and_check(
                txns, result=res, logical=atomics.logical(spec, state),
                version=state.version,
                msg=f"{strategy} w={w} step {step}")


def test_mcas_all_match_conflicts_serialize():
    """Every txn expects the live values of overlapping cells: exactly the
    txns whose cells were untouched by earlier commits succeed, and the
    oracle confirms the claimed (round, fail<commit, id) order."""
    n, k, w, t = 6, 2, 2, 8
    rng = np.random.default_rng(3)
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=64)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = atomics.init(spec, init)
    slot = np.stack([rng.choice(n, size=w, replace=False)
                     for _ in range(t)]).astype(np.int32)
    txns = atomics.make_txns(slot, init[slot],
                             rng.integers(0, 2 ** 32, (t, w, k),
                                          dtype=np.uint32), k=k)
    state, res = atomics.mcas(spec, state, txns)
    assert bool(np.asarray(res.success)[0])      # lowest id always commits
    TxnOracle(n, k, initial=init).step_and_check(
        txns, result=res, logical=atomics.logical(spec, state),
        version=state.version, msg="all-match conflicts")


@pytest.mark.parametrize("policy", [BackoffPolicy("const", 2),
                                    BackoffPolicy("exp", 1, 4)])
def test_mcas_backoff_policies_preserve_semantics(policy):
    """Dice-style abort backoff changes WHEN losers retry, never what the
    batch means: the claimed order still replays exactly."""
    n, k, w, t = 4, 2, 2, 6
    rng = np.random.default_rng(11)
    spec = atomics.AtomicSpec(n, k, "indirect", p_max=64)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = atomics.init(spec, init)
    oracle = TxnOracle(n, k, initial=init)
    for step in range(3):
        txns = txn_batch(rng, t=t, w=w, n=n, k=k, current=oracle.data,
                         match_frac=0.9)
        state, res = atomics.mcas(spec, state, txns, policy=policy)
        oracle.step_and_check(
            txns, result=res, logical=atomics.logical(spec, state),
            version=state.version, msg=f"policy {policy.kind} step {step}")


def test_mcas_aborted_txns_leave_no_trace():
    n, k = 4, 2
    spec = atomics.AtomicSpec(n, k, "cached_wf", p_max=32)
    init = np.arange(n * k, dtype=np.uint32).reshape(n, k)
    state = atomics.init(spec, init)
    txns = atomics.make_txns(
        [[0, 1], [2, 3]],
        expected=np.full((2, 2, k), 999, np.uint32),     # all stale
        desired=np.zeros((2, 2, k), np.uint32), k=k)
    state, res = atomics.mcas(spec, state, txns)
    assert not np.asarray(res.success).any()
    np.testing.assert_array_equal(np.asarray(atomics.logical(spec, state)),
                                  init)
    np.testing.assert_array_equal(np.asarray(state.version), np.zeros(n))
    # the failure witness is the consistent read that refused them
    np.testing.assert_array_equal(np.asarray(res.witness)[0], init[[0, 1]])


def test_mcas_is_cas_semantics_not_llsc():
    """A->B->A between mcas calls: expected compares VALUES, so the txn
    commits (unlike SC, which compares versions) — and the oracle agrees."""
    n, k = 2, 2
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=16)
    init = np.asarray([[1, 2], [3, 4]], np.uint32)
    state = atomics.init(spec, init)
    oracle = TxnOracle(n, k, initial=init)
    for payload in ([[9, 9]], [[1, 2]]):                 # A -> B -> A
        state, _, _, _, _ = atomics.apply(
            spec, state, atomics.stores([0], np.asarray(payload, np.uint32),
                                        k=k))
        oracle.version[0] += 2
    oracle.data[0] = [1, 2]
    txns = atomics.make_txns([[0, 1]], expected=init[None][:, [0, 1]],
                             desired=np.full((1, 2, k), 7, np.uint32), k=k)
    state, res = atomics.mcas(spec, state, txns)
    assert bool(np.asarray(res.success)[0])
    oracle.step_and_check(txns, result=res,
                          logical=atomics.logical(spec, state),
                          version=state.version, msg="aba commits")


def test_make_txns_validation():
    with pytest.raises(ValueError, match="duplicate slots"):
        atomics.make_txns([[1, 1]], k=2)
    with pytest.raises(ValueError, match="mismatched k"):
        atomics.make_txns([[0, 1]],
                          desired=np.zeros((1, 2, 3), np.uint32), k=2)
    with pytest.raises(ValueError, match="rank-2"):
        atomics.make_txns([0, 1], k=2)
    with pytest.raises(ValueError, match="txn word width"):
        spec = atomics.AtomicSpec(4, 3, "cached_me", p_max=8)
        atomics.mcas(spec, atomics.init(spec),
                     atomics.make_txns([[0]], k=2))
    # padding lanes (-1) are allowed and skipped
    t = atomics.make_txns([[0, -1]], k=2)
    assert t.w == 2


def test_mcas_plugin_strategy():
    """A strategy registered HERE runs MCAS unchanged (ISSUE 4 acceptance:
    the txn layer is registry-dispatched)."""
    class PlainCloneTxn(atomics.StrategyImpl):
        name = "txn_plugin_check"

    atomics.register_strategy(PlainCloneTxn(), overwrite=True)
    try:
        rng = np.random.default_rng(7)
        n, k, w, t = 6, 2, 2, 6
        spec = atomics.AtomicSpec(n, k, "txn_plugin_check", p_max=64)
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        state = atomics.init(spec, init)
        oracle = TxnOracle(n, k, initial=init)
        for step in range(3):
            txns = txn_batch(rng, t=t, w=w, n=n, k=k, current=oracle.data)
            state, res = atomics.mcas(spec, state, txns)
            oracle.step_and_check(
                txns, result=res, logical=atomics.logical(spec, state),
                version=state.version, msg=f"plugin step {step}")
    finally:
        atomics.unregister_strategy("txn_plugin_check")


# ---------------------------------------------------------------------------
# Version lists: timestamped snapshot reads + bounded-chain honesty.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LOCKFREE)
def test_versionlist_snapshot_reads(strategy):
    spec = VersionSpec(n=4, k=2, depth=3, strategy=strategy, p_max=32)
    st = vl.init(spec, np.zeros((4, 2), np.uint32))
    written = {0: {0: [0, 0]}, 1: {0: [0, 0]}}            # slot -> ts -> val
    for ts in range(1, 7):
        slot = ts % 2
        val = [ts, ts * 10]
        st = vl.publish(spec, st, [slot], [val], [ts])
        written[slot][ts] = val
    # every retained (slot, ts) answers exactly; evicted ones refuse
    for slot in (0, 1):
        tss = sorted(written[slot])
        for q_ts in range(0, 8):
            vals, fts, ok = vl.snapshot_read(spec, st, [slot], [q_ts])
            want_ts = max((x for x in tss if x <= q_ts), default=None)
            retained = tss[-spec.depth:]
            if want_ts is not None and want_ts in retained:
                assert bool(ok[0]), (slot, q_ts)
                assert int(fts[0]) == want_ts
                np.testing.assert_array_equal(np.asarray(vals[0]),
                                              written[slot][want_ts])
            else:
                assert not bool(ok[0]), (slot, q_ts)     # evicted: honest


def test_versionlist_multi_slot_snapshot_is_consistent():
    """snapshot_read of an arbitrary slot SET at one ts returns the values
    that were all simultaneously newest at that ts."""
    spec = VersionSpec(n=3, k=1, depth=4, strategy="cached_me", p_max=32)
    st = vl.init(spec)
    log = []                               # (ts, snapshot-of-all-slots)
    state_now = [0, 0, 0]
    rng = np.random.default_rng(5)
    for ts in range(1, 9):
        slot = int(rng.integers(0, 3))
        state_now[slot] = ts * 100 + slot
        st = vl.publish(spec, st, [slot], [[state_now[slot]]], [ts])
        log.append((ts, list(state_now)))
    for ts, want in log[-3:]:              # within every chain's window
        vals, _, ok = vl.snapshot_read(spec, st, [0, 1, 2], [ts] * 3)
        assert bool(np.asarray(ok).all())
        np.testing.assert_array_equal(np.asarray(vals)[:, 0], want)


def test_versionlist_publish_validation():
    spec = VersionSpec(n=4, k=1, depth=2)
    st = vl.init(spec)
    with pytest.raises(ValueError, match="distinct"):
        vl.publish(spec, st, [1, 1], [[1], [2]], [1, 2])
    with pytest.raises(ValueError, match="depth"):
        VersionSpec(n=4, k=1, depth=1)


# ---------------------------------------------------------------------------
# Transactional map: serializable read-set/write-set txns vs MapOracle.
# ---------------------------------------------------------------------------

def _fn_sum_plus_one(rv, rf):
    """Write value = sum of the read set + 1 (broadcast over W=1)."""
    return rv.sum(axis=1, keepdims=True) + 1


def _fn_copy_reads(rv, rf):
    """Write W values = the R read values (requires R == W)."""
    return rv


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_txn_map_counter_increments_serialize(strategy):
    """T txns all read-modify-write the SAME key: serializability forces
    T distinct rounds and a final value of exactly T."""
    t = 5
    hs = atomics.HashSpec(16, vw=1, strategy=strategy, p_max=64)
    state = ch.init_hash(hs)
    txns = txn_map.make_map_txns(np.full((t, 1), 9, np.uint32),
                                 np.full((t, 1), 9, np.uint32))
    state, res = txn_map.transact(hs, state, txns, _fn_sum_plus_one)
    assert int(res.rounds) == t                     # one commit per round
    oracle = MapOracle(vw=1)
    oracle.step_and_check(txns, _fn_sum_plus_one, result=res,
                          items=ch.items(state, inline=hs.inline, vw=1),
                          msg=f"counter {strategy}")
    assert oracle.model[9][0] == t


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_txn_map_random_txns_match_oracle(strategy):
    rng = np.random.default_rng(zlib.crc32(strategy.encode()) ^ 0xA5)
    hs = atomics.HashSpec(32, vw=2, strategy=strategy, p_max=128)
    state = ch.init_hash(hs)
    oracle = MapOracle(vw=2)
    t, r, w, key_space = 6, 2, 2, 12
    for step in range(4):
        txns = txn_map.make_map_txns(
            rng.integers(0, key_space, (t, r)).astype(np.uint32),
            np.stack([rng.choice(key_space, size=w, replace=False)
                      for _ in range(t)]).astype(np.uint32),
            read_mask=rng.random((t, r)) < 0.8,
            write_del=rng.random((t, w)) < 0.25)
        state, res = txn_map.transact(hs, state, txns, _fn_copy_reads)
        oracle.step_and_check(
            txns, _fn_copy_reads, result=res,
            items=ch.items(state, inline=hs.inline, vw=2),
            msg=f"map {strategy} step {step}")


def test_txn_map_provided_write_values_and_deletes():
    """fn=None data transactions (the serving bookkeeping shape): deletes
    + inserts commit atomically with the read set validating."""
    hs = atomics.HashSpec(16, vw=1, strategy="cached_me", p_max=64)
    state = ch.init_hash(hs)
    seed = txn_map.make_map_txns(
        np.zeros((1, 1), np.uint32), np.asarray([[1, 2, 3]], np.uint32),
        read_mask=np.zeros((1, 1), bool),
        write_value=np.asarray([[[10], [20], [30]]], np.uint32))
    state, _ = txn_map.transact(hs, state, seed, None)
    txns = txn_map.make_map_txns(
        np.asarray([[1, 2]], np.uint32), np.asarray([[1, 4]], np.uint32),
        write_del=np.asarray([[True, False]]),
        write_value=np.asarray([[[0], [40]]], np.uint32))
    state, res = txn_map.transact(hs, state, txns, None)
    items = {k: int(v[0]) for k, v in
             ch.items(state, inline=hs.inline, vw=1).items()}
    assert items == {2: 20, 3: 30, 4: 40}
    np.testing.assert_array_equal(np.asarray(res.read_found)[0], [1, 1])
    np.testing.assert_array_equal(np.asarray(res.read_value)[0, :, 0],
                                  [10, 20])


def test_txn_map_plugin_strategy():
    class PlainCloneMap(atomics.StrategyImpl):
        name = "txnmap_plugin_check"

    atomics.register_strategy(PlainCloneMap(), overwrite=True)
    try:
        hs = atomics.HashSpec(16, vw=1, strategy="txnmap_plugin_check",
                              p_max=64)
        state = ch.init_hash(hs)
        t = 4
        txns = txn_map.make_map_txns(np.full((t, 1), 3, np.uint32),
                                     np.full((t, 1), 3, np.uint32))
        state, res = txn_map.transact(hs, state, txns, _fn_sum_plus_one)
        oracle = MapOracle(vw=1)
        oracle.step_and_check(txns, _fn_sum_plus_one, result=res,
                              items=ch.items(state, inline=True, vw=1),
                              msg="map plugin")
    finally:
        atomics.unregister_strategy("txnmap_plugin_check")


def test_make_map_txns_validation():
    with pytest.raises(ValueError, match="duplicate keys"):
        txn_map.make_map_txns(np.zeros((1, 1), np.uint32),
                              np.asarray([[5, 5]], np.uint32))
    with pytest.raises(ValueError, match="rank-2"):
        txn_map.make_map_txns(np.zeros((2,), np.uint32),
                              np.zeros((2, 1), np.uint32))
    with pytest.raises(ValueError, match="txn counts"):
        txn_map.make_map_txns(np.zeros((2, 1), np.uint32),
                              np.zeros((3, 1), np.uint32))


# ---------------------------------------------------------------------------
# Facade: the txn layer is reachable from repro.atomics.
# ---------------------------------------------------------------------------

def test_atomics_facade_exports_txn_layer():
    assert atomics.mcas is txn_mcas.mcas
    assert atomics.make_txns is txn_mcas.make_txns
    assert atomics.TxnBatch is txn_mcas.TxnBatch
    assert atomics.VersionSpec is VersionSpec
    assert atomics.txn.transact is txn_map.transact
    assert hasattr(atomics.dist, "mcas")
