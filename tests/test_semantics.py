"""Property tests: the vectorized linearizer is bit-identical to the oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import semantics as sem


def _random_state(rng, n, k):
    data = rng.integers(0, 2**32, size=(n, k), dtype=np.uint32)
    ver = np.zeros((n,), dtype=np.uint32)
    return data, ver


def _check_batch(data, ver, ops):
    ref_data, ref_ver, ref_res = sem.apply_batch_reference(data, ver, ops)
    out_data, out_ver, res, stats = sem.apply_batch(
        jnp.asarray(data), jnp.asarray(ver), ops
    )
    np.testing.assert_array_equal(np.asarray(out_data), ref_data)
    np.testing.assert_array_equal(np.asarray(out_ver), ref_ver)
    np.testing.assert_array_equal(np.asarray(res.value), ref_res.value)
    np.testing.assert_array_equal(np.asarray(res.success), ref_res.success)
    return stats


def test_all_loads():
    rng = np.random.default_rng(0)
    data, ver = _random_state(rng, 16, 4)
    ops = sem.make_op_batch(
        kind=np.full(8, sem.LOAD), slot=rng.integers(0, 16, 8), k=4
    )
    stats = _check_batch(data, ver, ops)
    assert int(stats.rounds) == 0
    assert int(stats.n_raced_loads) == 0


def test_all_stores_same_slot():
    rng = np.random.default_rng(1)
    data, ver = _random_state(rng, 4, 2)
    p = 7
    ops = sem.make_op_batch(
        kind=np.full(p, sem.STORE),
        slot=np.zeros(p, np.int32),
        desired=rng.integers(0, 2**32, (p, 2), dtype=np.uint32),
        k=2,
    )
    stats = _check_batch(data, ver, ops)
    assert int(stats.rounds) == p  # fully serialized


def test_cas_chain():
    # CAS chain: each CAS expects the previous CAS's desired value.
    n, k, p = 2, 3, 6
    data = np.zeros((n, k), np.uint32)
    ver = np.zeros((n,), np.uint32)
    desired = np.arange(1, p + 1, dtype=np.uint32)[:, None] * np.ones(k, np.uint32)
    expected = np.concatenate([np.zeros((1, k), np.uint32), desired[:-1]])
    ops = sem.OpBatch(
        jnp.full((p,), sem.CAS, jnp.int32),
        jnp.zeros((p,), jnp.int32),
        jnp.asarray(expected),
        jnp.asarray(desired),
    )
    stats = _check_batch(data, ver, ops)
    assert int(stats.n_cas_fail) == 0


def test_cas_all_same_expected_one_wins():
    n, k, p = 1, 2, 5
    data = np.zeros((n, k), np.uint32)
    ver = np.zeros((n,), np.uint32)
    expected = np.zeros((p, k), np.uint32)
    desired = (np.arange(p, dtype=np.uint32)[:, None] + 1) * np.ones(k, np.uint32)
    ops = sem.OpBatch(
        jnp.full((p,), sem.CAS, jnp.int32), jnp.zeros((p,), jnp.int32),
        jnp.asarray(expected), jnp.asarray(desired),
    )
    stats = _check_batch(data, ver, ops)
    assert int(stats.n_cas_fail) == p - 1


def test_idle_lanes_ignored():
    rng = np.random.default_rng(3)
    data, ver = _random_state(rng, 8, 2)
    kind = np.array([sem.IDLE, sem.LOAD, sem.IDLE, sem.STORE], np.int32)
    ops = sem.make_op_batch(
        kind=kind, slot=np.array([0, 1, 2, 3], np.int32),
        desired=rng.integers(0, 2**32, (4, 2), dtype=np.uint32), k=2,
    )
    _check_batch(data, ver, ops)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 32),
    k=st.integers(1, 8),
    p=st.integers(1, 64),
    update_frac=st.floats(0.0, 1.0),
    zipf=st.sampled_from([0.0, 1.2, 3.0]),
)
def test_linearizable_property(seed, n, k, p, update_frac, zipf):
    rng = np.random.default_rng(seed)
    data, ver = _random_state(rng, n, k)
    ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=update_frac,
                           zipf=zipf, current=data)
    _check_batch(data, ver, ops)


def test_version_parity_even_after_batches():
    rng = np.random.default_rng(7)
    data, ver = _random_state(rng, 8, 2)
    data_j, ver_j = jnp.asarray(data), jnp.asarray(ver)
    for step in range(3):
        ops = sem.random_batch(rng, p=16, n=8, k=2, update_frac=0.8,
                               current=np.asarray(data_j))
        data_j, ver_j, _, _ = sem.apply_batch(data_j, ver_j, ops)
    assert np.all(np.asarray(ver_j) % 2 == 0)
