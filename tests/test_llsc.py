"""repro.sync linearizability tests: LL/SC vs the sequential oracle under
adversarial interleavings (ABA, lapped linker), atomic-copy overlap chains,
MPMC queue FIFO / full / empty races — across all four lock-free strategies.

Property sweeps here draw from seeded numpy RNGs directly (no hypothesis
dependency) so they run identically under the real package or the shim.
"""

import numpy as np
import pytest

from oracle import TableOracle
from repro import atomics
from repro.core import bigatomic as ba
from repro.sync import atomic_copy as ac
from repro.sync import llsc
from repro.sync.queue import DEQ, ENQ, BackoffPolicy, BigQueue

LOCKFREE = ["seqlock", "indirect", "cached_wf", "cached_me"]

_SYNC_KINDS = np.asarray([atomics.LL, atomics.SC, atomics.VALIDATE,
                          atomics.IDLE], np.int32)


def _random_sync_batch(rng, ref_ctx, *, p, n, k):
    """Mixed LL/SC/VALIDATE/IDLE batch; SC/VALIDATE lanes mostly target
    their link (unified kinds)."""
    kind = _SYNC_KINDS[rng.integers(0, 4, p)]
    slot = rng.integers(0, n, p).astype(np.int32)
    linked = np.asarray(ref_ctx.linked)
    lslot = np.asarray(ref_ctx.slot)
    for i in range(p):
        if kind[i] in (atomics.SC, atomics.VALIDATE) and linked[i] \
                and rng.random() < 0.7:
            slot[i] = lslot[i]
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.make_ops(kind, slot, desired=desired, k=k)


# ---------------------------------------------------------------------------
# LL/SC vs the shared sequential oracle (tests/oracle.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LOCKFREE)
def test_sync_batches_match_oracle(strategy):
    rng = np.random.default_rng(hash(strategy) % 2 ** 31)
    for trial in range(4):
        n = int(rng.integers(2, 16))
        k = int(rng.integers(1, 6))
        p = int(rng.integers(1, 24))
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        spec = atomics.AtomicSpec(n, k, strategy, p_max=64)
        state = atomics.init(spec, init)
        ctx = atomics.init_ctx(p, k)
        oracle = TableOracle(n, k, p, initial=init)
        for step in range(5):
            ops = _random_sync_batch(rng, oracle.ctx, p=p, n=n, k=k)
            state, ctx, res, stats, traffic = atomics.apply(
                spec, state, ops, ctx)
            oracle.step_and_check(
                ops, result=res, logical=atomics.logical(spec, state),
                version=state.version, ctx=ctx,
                msg=f"{strategy} trial {trial} step {step}")


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_sc_defeats_aba(strategy):
    """Value restored to its linked bytes after intervening commits: a CAS
    would succeed (the ABA bug); SC must fail because the version moved."""
    n, k = 4, 3
    a = np.arange(n * k, dtype=np.uint32).reshape(n, k)
    state = ba.init(n, k, strategy, p_max=16, initial=a)
    ctx = llsc.init_ctx(1, k)
    ctx, vals = llsc.ll(state, ctx, [2], strategy=strategy, k=k)
    original = np.asarray(vals[0])
    # store A -> B -> A through the ordinary update path
    spec = atomics.AtomicSpec(n, k, strategy, p_max=16)
    b = (original + 1).astype(np.uint32)
    for payload in (b, original):
        state, _, _, _, _ = atomics.apply(
            spec, state, atomics.stores([2], payload[None], k=k))
    np.testing.assert_array_equal(
        np.asarray(ba.logical(state, strategy))[2], original)  # bytes match
    assert not bool(llsc.validate(state, ctx, [2], strategy=strategy, k=k)[0])
    state, ctx, succ = llsc.sc(state, ctx, [2], original[None],
                               strategy=strategy, k=k)
    assert not bool(succ[0])                                   # SC refuses
    # the cell is untouched by the failed SC
    np.testing.assert_array_equal(
        np.asarray(ba.logical(state, strategy))[2], original)


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_lapped_linker_fails(strategy):
    """A lane that holds its link while other lanes commit many times (a
    descheduled 'lapped' linker) must fail its eventual SC and validate."""
    n, k, p = 4, 2, 8
    state = ba.init(n, k, strategy, p_max=64)
    ctx = llsc.init_ctx(p, k)
    ctx, _ = llsc.ll(state, ctx, np.zeros(p, np.int32), strategy=strategy,
                     k=k)
    # lanes 1..p-1 commit in turn (each re-linked just before its SC, so
    # each succeeds); lane 0 sleeps on its original link the whole time
    spec = atomics.AtomicSpec(n, k, strategy, p_max=64)
    for lane in range(1, p):
        kind = np.full(p, atomics.IDLE, np.int32)
        kind[lane] = atomics.SC
        desired = np.full((p, k), lane, np.uint32)
        ops = atomics.make_ops(kind, np.zeros(p, np.int32), desired=desired,
                               k=k)
        state, ctx, res, _, _ = atomics.apply(spec, state, ops, ctx)
        assert bool(np.asarray(res.success)[lane])
        if lane + 1 < p:
            kind = np.full(p, atomics.IDLE, np.int32)
            kind[lane + 1] = atomics.LL
            ops = atomics.make_ops(kind, np.zeros(p, np.int32), k=k)
            state, ctx, _, _, _ = atomics.apply(spec, state, ops, ctx)
    assert not bool(
        llsc.validate(state, ctx, [0], strategy=strategy, k=k)[0])
    state, ctx, succ = llsc.sc(state, ctx, [0], np.zeros((1, k), np.uint32),
                               strategy=strategy, k=k)
    assert not bool(succ[0])


def test_one_sc_per_cell_per_batch():
    """All p lanes link the same cell, then all SC at once: exactly the
    first lane commits; every other lane is stale by construction."""
    n, k, p = 2, 2, 8
    state = ba.init(n, k, "cached_me", p_max=32)
    ctx = llsc.init_ctx(p, k)
    ctx, _ = llsc.ll(state, ctx, np.zeros(p, np.int32), strategy="cached_me",
                     k=k)
    desired = np.tile(np.arange(p, dtype=np.uint32)[:, None], (1, k))
    state, ctx, succ = llsc.sc(state, ctx, np.zeros(p, np.int32), desired,
                               strategy="cached_me", k=k)
    succ = np.asarray(succ)
    assert succ[0] and not succ[1:].any()
    np.testing.assert_array_equal(
        np.asarray(ba.logical(state, "cached_me"))[0], desired[0])
    assert not np.asarray(ctx.linked).any()    # every SC consumed its link


# ---------------------------------------------------------------------------
# Atomic copy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LOCKFREE)
def test_atomic_copy_overlap_matches_oracle(strategy):
    rng = np.random.default_rng(7)
    n, k = 10, 4
    spec = atomics.AtomicSpec(n, k, strategy, p_max=64)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = atomics.init(spec, init)
    ref_data, ref_ver = init.copy(), np.zeros(n, np.uint32)
    for trial in range(6):
        q = int(rng.integers(1, 10))
        src = rng.integers(0, n, q)
        dst = rng.integers(0, n, q)
        ref_data, ref_ver = ac.copy_batch_reference(ref_data, ref_ver,
                                                    src, dst)
        state, _waves = ac.copy_batch(spec, state, src, dst)
        np.testing.assert_array_equal(
            np.asarray(ba.logical(state, strategy)), ref_data,
            err_msg=f"{strategy} trial {trial}")
        np.testing.assert_array_equal(np.asarray(state.version), ref_ver)


def test_atomic_copy_chain_same_batch():
    """copy(a->b) and copy(b->c) in one batch: c gets a's value (lane order),
    proving the copies don't tear or reorder."""
    n, k = 4, 2
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=16)
    init = np.asarray([[1, 1], [2, 2], [3, 3], [4, 4]], np.uint32)
    state = atomics.init(spec, init)
    state, _ = ac.copy_batch(spec, state, [0, 1], [1, 2])
    got = np.asarray(ba.logical(state, "seqlock"))
    np.testing.assert_array_equal(got[1], [1, 1])
    np.testing.assert_array_equal(got[2], [1, 1])   # chained through b


# ---------------------------------------------------------------------------
# MPMC queue
# ---------------------------------------------------------------------------

POLICIES = [BackoffPolicy("none"), BackoffPolicy("const", 1),
            BackoffPolicy("exp", 1, 4)]


def _queue_oracle(capacity, kinds, values):
    """Sequential queue applying ops in lane order (policy-'none' contract
    for uniform batches)."""
    q: list[int] = []
    out = np.zeros(len(kinds), np.uint32)
    succ = np.zeros(len(kinds), bool)
    for i, kd in enumerate(kinds):
        if kd == ENQ:
            if len(q) < capacity:
                q.append(int(values[i]))
                succ[i] = True
        elif kd == DEQ:
            if q:
                out[i] = q.pop(0)
                succ[i] = True
    return out, succ, q


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_queue_uniform_batches_match_oracle(strategy):
    """With policy 'none', uniform enqueue/dequeue batches commit in lane
    order — bit-identical to the sequential oracle, across strategies."""
    rng = np.random.default_rng(11)
    C = 5
    q = BigQueue(C, k=2, strategy=strategy)
    model: list[int] = []
    for step in range(6):
        p = int(rng.integers(1, 8))
        if step % 2 == 0:
            vals = rng.integers(0, 2 ** 32, p, dtype=np.uint32)
            succ = q.enqueue_batch(vals)
            _, ref_succ, left = _queue_oracle(C, np.full(p, ENQ), vals)
            want = [v for v, s in zip(vals, ref_succ) if s]
            assert list(succ) == list(ref_succ) or \
                succ.sum() == ref_succ.sum()
            np.testing.assert_array_equal(succ, ref_succ)
            model = model[:]  # lane-order commits
            for v in want:
                if len(model) < C:
                    model.append(int(v))
        else:
            out, succ = q.dequeue_batch(p)
            take = min(p, len(model))
            assert succ.sum() == take
            got = [int(out[i, 0]) for i in np.nonzero(succ)[0]]
            assert got == model[:take], (got, model[:take])
            model = model[take:]
        assert len(q) == len(model)


@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.kind)
@pytest.mark.parametrize("strategy", ["seqlock", "cached_me"])
def test_queue_linearizable_under_backoff(strategy, policy):
    """Mixed races with arbitrary backoff: the recorded commit order must be
    a valid FIFO history (tickets contiguous, value of ticket t dequeued at
    ticket t) and per-producer order must hold across batches."""
    rng = np.random.default_rng(13)
    C, p = 4, 6
    q = BigQueue(C, k=2, strategy=strategy, policy=policy)
    lane_sent: dict[int, list[int]] = {i: [] for i in range(p)}
    dequeued: list[int] = []
    serial = 0
    for step in range(8):
        kinds = rng.integers(0, 3, p)
        vals = np.zeros((p, 1), np.uint32)
        for i in np.nonzero(kinds == ENQ)[0]:
            vals[i, 0] = serial * p + i        # unique, encodes producer
            serial += 1
        out, succ, _rounds = q.run_batch(kinds, vals)
        for i in np.nonzero((kinds == ENQ) & succ)[0]:
            lane_sent[i].append(int(vals[i, 0]))
        for i in np.nonzero((kinds == DEQ) & succ)[0]:
            dequeued.append(int(out[i, 0]))
    # drain what's left
    out, succ = q.dequeue_batch(C)
    dequeued += [int(out[i, 0]) for i in np.nonzero(succ)[0]]
    assert len(q) == 0

    log = q.commit_log
    enq_t = [t for kind, _, t in log if kind == "enq"]
    deq_t = [t for kind, _, t in log if kind == "deq"]
    assert enq_t == list(range(len(enq_t)))    # tickets dense, in order
    assert deq_t == list(range(len(deq_t)))
    # FIFO: dequeue stream == enqueue-commit value stream
    enq_vals = []
    it = iter(log)
    by_ticket = {}
    for kind, lane, t in log:
        if kind == "enq":
            by_ticket[t] = (lane, t)
    # reconstruct enqueue values from lanes' send lists in commit order
    lane_iters = {i: iter(v) for i, v in lane_sent.items()}
    for kind, lane, t in log:
        if kind == "enq":
            enq_vals.append(next(lane_iters[lane]))
    assert dequeued == enq_vals[:len(dequeued)]
    # per-producer FIFO: each lane's values appear in send order
    for i, sent in lane_sent.items():
        got = [v for v in dequeued if v % p == i and v in sent]
        assert got == [v for v in sent if v in dequeued]


def test_queue_full_and_empty_races():
    q = BigQueue(3, k=2)
    assert q.dequeue_batch(2)[1].sum() == 0            # empty from the start
    succ = q.enqueue_batch(np.arange(5, dtype=np.uint32))
    assert succ.sum() == 3 and len(q) == 3             # 2 lanes hit full
    # mixed full race: one deq frees a slot, so exactly one more enq lands
    out, succ, _ = q.run_batch([ENQ, DEQ, ENQ],
                               np.asarray([[7], [0], [9]], np.uint32))
    assert succ[1] and int(out[1, 0]) == 0
    assert succ[0] != succ[2] or succ[0]               # >=1 enqueue landed
    assert len(q) == 3                                 # still full


def test_queue_payload_rides_big_atomic():
    """k > 2: a multi-word payload travels with its seq tag in one atomic
    cell — no torn (tag, payload) pairs even under contention."""
    q = BigQueue(4, k=4, strategy="cached_wf")
    vals = np.asarray([[1, 2, 3], [4, 5, 6], [7, 8, 9]], np.uint32)
    assert q.enqueue_batch(vals).all()
    out, succ = q.dequeue_batch(3)
    assert succ.all()
    np.testing.assert_array_equal(out, vals)


def test_queue_backoff_reduces_wasted_attempts():
    """The Dice et al. claim, batch-step edition: under heavy same-counter
    contention a bounded backoff wastes fewer failed SCs than no backoff."""
    def failed_scs(policy):
        q = BigQueue(64, k=2, policy=policy, p_max=64)
        q.enqueue_batch(np.arange(32, dtype=np.uint32))
        before = len(q.commit_log)
        out, succ, rounds = q.run_batch(np.full(32, DEQ))
        assert succ.all()
        return rounds

    r_none = failed_scs(BackoffPolicy("none"))
    r_exp = failed_scs(BackoffPolicy("exp", 1, 4))
    # both drain; the schedules differ but stay within the progress bound
    assert r_none >= 32 and r_exp >= 32


# ---------------------------------------------------------------------------
# Fused Pallas commit kernel (interpret mode)
# ---------------------------------------------------------------------------

def test_llsc_commit_kernel_matches_ref():
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.llsc_commit import llsc_commit_round

    rng = np.random.default_rng(3)
    for n, k, p in [(8, 4, 6), (32, 8, 16), (16, 2, 16), (64, 128, 8)]:
        data = jnp.asarray(rng.integers(0, 2 ** 32, (n + 1, k),
                                        dtype=np.uint32))
        meta = jnp.asarray((rng.integers(0, 8, (n + 1, 2)) * 2)
                           .astype(np.uint32))
        slots = np.full(p, n, np.int32)
        n_live = min(p - 1, n)
        slots[:n_live] = rng.choice(n, n_live, replace=False)
        live = (slots < n).astype(np.int32)
        link_ver = np.asarray(meta)[np.minimum(slots, n - 1), 0] \
            .astype(np.uint32)
        link_ver[::3] += 2                       # stale links must fail
        desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
        outs = llsc_commit_round(data, meta, jnp.asarray(slots),
                                 jnp.asarray(live), jnp.asarray(link_ver),
                                 jnp.asarray(desired), interpret=True)
        refs = ref.llsc_commit_round_ref(data, meta, slots, live, link_ver,
                                         desired)
        for a, b in zip(outs, refs):
            np.testing.assert_array_equal(np.asarray(a)[:n],
                                          np.asarray(b)[:n])


def test_llsc_commit_kernel_agrees_with_apply_sync():
    """The fused kernel commits exactly what the jnp SC path commits, for a
    winners-only round extracted from a contended batch."""
    import jax.numpy as jnp

    from repro.kernels.llsc_commit import llsc_commit_round

    n, k, p = 8, 4, 12
    rng = np.random.default_rng(21)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = ba.init(n, k, "seqlock", p_max=32, initial=init)
    ctx = llsc.init_ctx(p, k)
    slots = rng.integers(0, n, p).astype(np.int32)
    ctx, _ = llsc.ll(state, ctx, slots, strategy="seqlock", k=k)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)

    # jnp path
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=32)
    state2, _, res, _, _ = atomics.apply(
        spec, state, atomics.make_ops(
            np.full(p, atomics.SC, np.int32), slots, desired=desired, k=k),
        ctx)

    # kernel path: feed ALL lanes; stale/duplicate losers carry link_ver
    # equal to the winner's so validation inside the kernel must arbitrate.
    # Distinct-slot contract -> keep first lane per slot only.
    first = np.zeros(p, bool)
    seen = set()
    for i, s in enumerate(slots):
        if s not in seen:
            seen.add(s)
            first[i] = True
    kslots = np.where(first, slots, n).astype(np.int32)
    data = jnp.concatenate([jnp.asarray(init),
                            jnp.zeros((1, k), jnp.uint32)])
    meta = jnp.zeros((n + 1, 2), jnp.uint32)
    d2, m2, succ, _ = llsc_commit_round(
        data, meta, jnp.asarray(kslots), jnp.asarray(first.astype(np.int32)),
        jnp.asarray(np.asarray(ctx.version)), jnp.asarray(desired),
        interpret=True)
    np.testing.assert_array_equal(np.asarray(d2)[:n],
                                  np.asarray(ba.logical(state2, "seqlock")))
    np.testing.assert_array_equal(np.asarray(m2)[:n, 0],
                                  np.asarray(state2.version))
    np.testing.assert_array_equal(np.asarray(succ)[:, 0].astype(bool),
                                  np.asarray(res.success) & first)
