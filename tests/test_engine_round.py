"""The fused engine round (kernels/engine_round.py) vs the pure-XLA
`engine.linearize` reference: interpret-mode kernel equivalence over mixed
op-kind batches x all four lock-free strategies x collision spectra, the
fast-path predicate's false-positive safety, the plug-in fallback path, and
the apply-layer re-trace/donation contracts (ISSUE 5)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import atomics
from repro.core import engine
from repro.kernels import engine_round

STRATEGIES = ["seqlock", "indirect", "cached_wf", "cached_me"]
SPECTRA = ["none", "low", "all_same"]
ALL_KINDS = [atomics.LOAD, atomics.STORE, atomics.CAS, atomics.IDLE,
             atomics.LL, atomics.SC, atomics.VALIDATE]


def make_table(n, k, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    ver = (rng.integers(0, 8, n) * 2).astype(np.uint32)
    return jnp.asarray(data), jnp.asarray(ver)


def make_batch(rng, n, k, p, spectrum, kinds=ALL_KINDS, data=None, ver=None):
    """A mixed batch + a LinkCtx with a mix of live/stale/mismatched links."""
    kind = rng.choice(np.asarray(kinds), p).astype(np.int32)
    if spectrum == "none":
        assert p <= n, "collision-free spectrum needs p <= n"
        slots = rng.choice(n, p, replace=False).astype(np.int32)
    elif spectrum == "low":
        slots = rng.integers(0, max(n // 8, 2), p).astype(np.int32)
    else:                                   # all_same: worst-case contention
        slots = np.full(p, rng.integers(0, n), np.int32)
    expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    if data is not None:                    # let ~half the CASes succeed
        cur = np.asarray(data)
        for i in range(p):
            if rng.random() < 0.5:
                expected[i] = cur[slots[i]]
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    ops = atomics.make_ops(kind, slots, expected, desired, k=k)

    # links: ~70% of SC/VALIDATE lanes name their own slot with the live
    # version (they can commit), the rest are stale or name another cell
    cslot = np.where(rng.random(p) < 0.7, slots,
                     rng.integers(-1, n, p)).astype(np.int32)
    vnow = np.asarray(ver)[np.clip(cslot, 0, n - 1)]
    cver = np.where(rng.random(p) < 0.8, vnow, vnow + 2).astype(np.uint32)
    ctx = engine.LinkCtx(
        slot=jnp.asarray(cslot), version=jnp.asarray(cver),
        value=jnp.asarray(
            rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)),
        linked=jnp.asarray(rng.random(p) < 0.8))
    return ops, ctx


def assert_rounds_equal(ref, out, label=""):
    names = ["data", "version", "ctx.slot", "ctx.version", "ctx.value",
             "ctx.linked", "res.value", "res.success", "rounds", "n_updates",
             "n_loads", "n_cas_fail", "n_raced_loads", "n_dirty_cells"]
    for name, a, b in zip(names, jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{label}: fused round diverges from linearize on {name}")


# ---------------------------------------------------------------------------
# Kernel round vs linearize: bit-identical on every in-contract batch.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["xla", "pallas"])
@pytest.mark.parametrize("spectrum", SPECTRA)
def test_round_matches_linearize_mixed_kinds(mode, spectrum):
    n, k, p = 32, 4, 24
    rng = np.random.default_rng(hash((mode, spectrum)) % 2 ** 31)
    data, ver = make_table(n, k)
    round_fn = engine_round.make_round(n, k, mode=mode, interpret=True)
    for trial in range(3):
        ops, ctx = make_batch(rng, n, k, p, spectrum, data=data, ver=ver)
        ref = engine.linearize(data, ver, ctx, ops)
        out = round_fn(data, ver, ctx, ops)
        assert_rounds_equal(ref, out, f"{mode}/{spectrum}/trial{trial}")
        data, ver = ref[0], ref[1]          # chain batches across state


@pytest.mark.parametrize("mode", ["xla", "pallas"])
def test_round_matches_linearize_odd_width_and_padding(mode):
    """p not a multiple of the lane tile and k=1 exercise kernel padding."""
    n, k, p = 16, 1, 11
    rng = np.random.default_rng(5)
    data, ver = make_table(n, k, seed=5)
    round_fn = engine_round.make_round(n, k, mode=mode, interpret=True,
                                       block=4)
    ops, ctx = make_batch(rng, n, k, p, "low", data=data, ver=ver)
    assert_rounds_equal(engine.linearize(data, ver, ctx, ops),
                        round_fn(data, ver, ctx, ops), "padding")


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("spectrum", SPECTRA)
def test_apply_matches_oracle_under_kernel_round(strategy, spectrum):
    """`atomics.apply` (which now routes through the strategy's lowered
    round) stays bit-identical to the sequential oracle for every layout."""
    n, k, p = 16, 4, 12
    rng = np.random.default_rng(hash((strategy, spectrum)) % 2 ** 31)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state = atomics.init(spec)
    ctx = atomics.init_ctx(p, k)
    for _ in range(3):
        d0 = np.asarray(atomics.logical(spec, state))
        v0 = np.asarray(state.version)
        ops, _ = make_batch(rng, n, k, p, spectrum, data=d0,
                            ver=state.version)
        pre_ctx = ctx
        state, ctx, res, stats, _ = atomics.apply(spec, state, ops, ctx)
        rd, rv, rctx, rres = engine.apply_ops_reference(d0, v0, pre_ctx, ops)
        np.testing.assert_array_equal(
            np.asarray(atomics.logical(spec, state)), rd)
        np.testing.assert_array_equal(np.asarray(state.version), rv)
        np.testing.assert_array_equal(np.asarray(res.value), rres.value)
        np.testing.assert_array_equal(np.asarray(res.success), rres.success)
        np.testing.assert_array_equal(np.asarray(ctx.linked), rctx.linked)
        np.testing.assert_array_equal(np.asarray(ctx.version), rctx.version)


def test_pallas_round_via_env_matches_default(monkeypatch):
    """BIGATOMIC_ENGINE_KERNEL=pallas (the CI kernel-exercise matrix) routes
    apply through the interpret-mode kernels and changes nothing — with the
    SAME spec, because the resolved mode rides the jit cache key (a
    mid-process env change must retrace, never reuse the other engine)."""
    n, k, p = 16, 4, 10
    rng = np.random.default_rng(11)
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=p)
    ops, _ = make_batch(rng, n, k, p, "low",
                        kinds=[atomics.LOAD, atomics.STORE, atomics.CAS],
                        data=atomics.init(spec).data,
                        ver=atomics.init(spec).version)
    ref_state, _, ref_res, _, _ = atomics.apply(spec, atomics.init(spec),
                                                ops)
    monkeypatch.setenv("BIGATOMIC_ENGINE_KERNEL", "pallas")
    state2, _, res2, _, _ = atomics.apply(spec, atomics.init(spec), ops)
    np.testing.assert_array_equal(np.asarray(ref_res.value),
                                  np.asarray(res2.value))
    np.testing.assert_array_equal(
        np.asarray(atomics.logical(spec, ref_state)),
        np.asarray(atomics.logical(spec, state2)))


# ---------------------------------------------------------------------------
# The fast-path predicate: false positives are impossible.
# ---------------------------------------------------------------------------

def test_predicate_rejects_colliding_writes():
    n, k, p = 16, 2, 8
    kind = np.full(p, atomics.STORE, np.int32)
    slots = np.zeros(p, np.int32)                    # all-same-slot writes
    ops = atomics.make_ops(kind, slots, k=k)
    assert not bool(engine_round.fast_path_ok(n, ops))


def test_predicate_rejects_out_of_range_and_accepts_disjoint():
    n, k = 16, 2
    ops = atomics.make_ops([atomics.LOAD, atomics.STORE],
                           [3, n + 2], k=k)          # active out-of-range
    assert not bool(engine_round.fast_path_ok(n, ops))
    ops = atomics.make_ops([atomics.LOAD, atomics.STORE, atomics.SC],
                           [3, 7, 11], k=k)
    assert bool(engine_round.fast_path_ok(n, ops))


def test_predicate_accepts_read_only_collisions():
    """Reads and validates commute: same-slot LOAD/LL/VALIDATE batches are
    still independent, and the fast round must agree with linearize."""
    n, k, p = 8, 2, 10
    rng = np.random.default_rng(3)
    kind = rng.choice(np.asarray([atomics.LOAD, atomics.LL,
                                  atomics.VALIDATE]), p).astype(np.int32)
    ops = atomics.make_ops(kind, np.zeros(p, np.int32), k=k)
    assert bool(engine_round.fast_path_ok(n, ops))
    data, ver = make_table(n, k, seed=3)
    ctx = atomics.init_ctx(p, k)
    for mode in ("xla", "pallas"):
        round_fn = engine_round.make_round(n, k, mode=mode, interpret=True)
        assert_rounds_equal(engine.linearize(data, ver, ctx, ops),
                            round_fn(data, ver, ctx, ops), mode)


def test_slow_kernel_negative_slot_is_failed_noop():
    """Out-of-contract active slots (here: negative) must never become a
    DMA index: the Pallas slow path treats them as failed no-ops and the
    rest of the batch executes normally."""
    n, k = 8, 2
    data, ver = make_table(n, k, seed=21)
    ctx = atomics.init_ctx(3, k)
    des = np.arange(3 * k, dtype=np.uint32).reshape(3, k) + 1
    ops = atomics.make_ops(
        [atomics.STORE, atomics.STORE, atomics.LOAD], [-1, 3, -5],
        desired=des, k=k)
    assert not bool(engine_round.fast_path_ok(n, ops))
    round_fn = engine_round.make_round(n, k, mode="pallas", interpret=True)
    d2, v2, _, res, _ = round_fn(data, ver, ctx, ops)
    # lane 1 commits; no other row (incl. the would-wrap rows) is touched
    expect = np.asarray(data).copy()
    expect[3] = des[1]
    np.testing.assert_array_equal(np.asarray(d2), expect)
    assert bool(res.success[1])
    assert not bool(res.success[0]) and not bool(res.success[2])
    np.testing.assert_array_equal(np.asarray(res.value[0]), 0)


def test_predicate_never_false_positive_property():
    """Random batches: whenever the predicate says fast, the batch really is
    read-only or duplicate-free among active in-range lanes."""
    n, k, p = 64, 2, 8
    rng = np.random.default_rng(7)
    hits = 0
    for trial in range(200):
        kind = rng.choice(np.asarray(ALL_KINDS), p).astype(np.int32)
        lo, hi = (-2, n + 2) if trial % 2 else (0, n)
        slots = rng.integers(lo, hi, p).astype(np.int32)
        ops = atomics.make_ops(kind, slots, k=k)
        fast = bool(engine_round.fast_path_ok(n, ops))
        active = kind != atomics.IDLE
        writes = active & np.isin(kind, [atomics.STORE, atomics.CAS,
                                         atomics.SC])
        in_range = (slots >= 0) & (slots < n)
        asl = slots[active]
        if fast:
            hits += 1
            assert np.all(in_range[active]), "fast with out-of-range slot"
            assert (not writes.any()) or len(np.unique(asl)) == len(asl), \
                "fast path accepted a colliding batch with writes"
    assert hits > 0                                   # the predicate fires


# ---------------------------------------------------------------------------
# Plug-in fallback: strategies without lower_round stay on linearize.
# ---------------------------------------------------------------------------

def test_plugin_strategy_falls_back_to_linearize():
    class PlainClone(atomics.StrategyImpl):
        name = "engine_round_test_plugin"

    impl = atomics.register_strategy(PlainClone, overwrite=True)
    try:
        assert impl.lower_round(atomics.AtomicSpec(8, 2, impl.name),
                                mode="pallas", interpret=True) is None
        spec = atomics.AtomicSpec(8, 2, impl.name, p_max=8)
        assert engine.round_for(spec) is engine.linearize
        # and the full apply path still matches the oracle
        rng = np.random.default_rng(9)
        state = atomics.init(spec)
        ops, _ = make_batch(rng, 8, 2, 8, "low", data=state.data,
                            ver=state.version)
        d0, v0 = np.asarray(state.data), np.asarray(state.version)
        ctx = atomics.init_ctx(8, 2)
        state2, _, res, _, _ = atomics.apply(spec, state, ops, ctx)
        rd, rv, _, rres = engine.apply_ops_reference(d0, v0, ctx, ops)
        np.testing.assert_array_equal(np.asarray(state2.data), rd)
        np.testing.assert_array_equal(np.asarray(res.success), rres.success)
    finally:
        atomics.unregister_strategy(impl.name)


def test_builtin_strategies_lower_their_round():
    for name in STRATEGIES:
        impl = atomics.get_strategy(name)
        fn = impl.lower_round(atomics.AtomicSpec(8, 2, name), mode="xla",
                              interpret=True)
        assert callable(fn) and fn is not engine.linearize
    for name in ("plain", "simplock"):
        impl = atomics.get_strategy(name)
        assert impl.lower_round(atomics.AtomicSpec(8, 2, name), mode="xla",
                                interpret=True) is None


def test_mode_off_is_pure_linearize(monkeypatch):
    monkeypatch.setenv("BIGATOMIC_ENGINE_KERNEL", "off")
    spec = atomics.AtomicSpec(8, 2, "cached_me", p_max=4)
    assert engine.round_for(spec) is engine.linearize


# ---------------------------------------------------------------------------
# llsc_commit.commit_round is subsumed by the fast-path kernel.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interpret", [False, True])
def test_commit_round_subsumed_matches_apply(interpret):
    from repro.kernels.llsc_commit import commit_round

    n, k, p = 8, 4, 6
    rng = np.random.default_rng(13)
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=p)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    slots = rng.choice(n, p, replace=False).astype(np.int32)

    state = atomics.init(spec, init)
    ctx = atomics.init_ctx(p, k)
    state, ctx, _, _, _ = atomics.apply(
        spec, state, atomics.sync_ops(np.full(p, atomics.LL), slots, k=k),
        ctx)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    # mark lanes 0/3 dead (slot == n), stale-link lane 1 (wrong cell)
    kslots = slots.copy()
    kslots[0] = n
    ctx = ctx._replace(slot=ctx.slot.at[1].set((int(slots[1]) + 1) % n))

    st_k, ctx_k, succ_k, wit_k = commit_round(
        spec, state, ctx, kslots, desired, interpret=interpret)

    kind = np.where(kslots < n, atomics.SC, atomics.IDLE).astype(np.int32)
    st_a, ctx_a, res, _, _ = atomics.apply(
        spec, state, atomics.make_ops(kind, kslots, desired=desired, k=k),
        ctx)
    np.testing.assert_array_equal(np.asarray(atomics.logical(spec, st_k)),
                                  np.asarray(atomics.logical(spec, st_a)))
    np.testing.assert_array_equal(np.asarray(st_k.version),
                                  np.asarray(st_a.version))
    np.testing.assert_array_equal(np.asarray(succ_k),
                                  np.asarray(res.success))
    np.testing.assert_array_equal(np.asarray(wit_k), np.asarray(res.value))
    np.testing.assert_array_equal(np.asarray(ctx_k.linked),
                                  np.asarray(ctx_a.linked))


# ---------------------------------------------------------------------------
# Re-trace hazard (ISSUE 5 satellite): canonicalization + donation.
# ---------------------------------------------------------------------------

def test_apply_does_not_retrace_on_weak_dtypes():
    from repro.analysis import tracing

    n, k, p = 8, 2, 4
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=p)
    state = atomics.init(spec)
    slots64 = np.arange(p, dtype=np.int64)           # numpy int64
    slots32 = jnp.arange(p, dtype=jnp.int32)         # committed int32
    ops_a = atomics.OpBatch(
        np.full(p, atomics.LOAD, np.int64), slots64,
        np.zeros((p, k), np.uint32), np.zeros((p, k), np.uint64))
    ops_b = atomics.OpBatch(
        jnp.full((p,), atomics.LOAD, jnp.int32), slots32,
        jnp.zeros((p, k), jnp.uint32), jnp.zeros((p, k), jnp.uint32))
    atomics.apply(spec, state, ops_b)                # establish the trace
    with tracing.assert_max_new_traces(engine._apply, 0):
        atomics.apply(spec, state, ops_a)            # differently typed
        atomics.apply(spec, state, ops_b)


def test_apply_donate_same_results():
    n, k, p = 8, 2, 4
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=p)
    ops = atomics.stores(np.arange(p), np.ones((p, k), np.uint32), k=k)
    ref, _, _, _, _ = atomics.apply(spec, atomics.init(spec), ops)
    out, _, _, _, _ = atomics.apply(spec, atomics.init(spec), ops,
                                    donate=True)
    np.testing.assert_array_equal(np.asarray(ref.data),
                                  np.asarray(out.data))
    np.testing.assert_array_equal(np.asarray(ref.version),
                                  np.asarray(out.version))
