"""Strategy-layer tests: all layouts give identical linearizable semantics,
honest reader protocols behave per the paper under torn (oversubscribed)
states, and space accounting matches Table 1 formulas."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bigatomic as ba
from repro.core import semantics as sem

ALL = [s.value for s in ba.Strategy]
LOCKFREE = ["indirect", "cached_wf", "cached_me"]
PROTOCOLS = ["seqlock", "indirect", "cached_wf", "cached_me", "simplock", "plain"]


def _mk(strategy, n=16, k=4, p_max=32, seed=0):
    rng = np.random.default_rng(seed)
    initial = rng.integers(0, 2**32, size=(n, k), dtype=np.uint32)
    return ba.BigAtomicTable(n, k, strategy, p_max, initial), initial, rng


@pytest.mark.parametrize("strategy", ALL)
def test_semantics_identical_across_strategies(strategy):
    tab, initial, rng = _mk(strategy)
    ref_data = initial.copy()
    ref_ver = np.zeros(16, np.uint32)
    for step in range(4):
        ops = sem.random_batch(rng, p=24, n=16, k=4, update_frac=0.6,
                               zipf=1.5, current=ref_data)
        ref_data, ref_ver, ref_res = sem.apply_batch_reference(
            ref_data, ref_ver, ops)
        res, stats, traffic = tab.apply(ops)
        np.testing.assert_array_equal(np.asarray(res.value), ref_res.value)
        np.testing.assert_array_equal(np.asarray(res.success), ref_res.success)
    np.testing.assert_array_equal(np.asarray(tab.logical()), ref_data)


@pytest.mark.parametrize("strategy", PROTOCOLS)
def test_read_protocol_matches_logical_when_quiescent(strategy):
    tab, initial, rng = _mk(strategy)
    ops = sem.random_batch(rng, p=24, n=16, k=4, update_frac=0.8,
                           current=initial)
    tab.apply(ops)
    slots = jnp.arange(16, dtype=jnp.int32)
    vals, ok = ba.read_protocol(tab.state, slots, strategy=strategy)
    assert bool(jnp.all(ok))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(tab.logical()))


# ---------------------------------------------------------------------------
# Torn-state simulation: the paper's oversubscription story.
# ---------------------------------------------------------------------------

def _torn(strategy, n=8, k=6):
    tab, initial, rng = _mk(strategy, n=n, k=k)
    old = np.asarray(tab.logical())[3].copy()
    new = rng.integers(0, 2**32, size=(k,), dtype=np.uint32)
    state = ba.begin_update(tab.state, 3, new, strategy=strategy)
    vals, ok = ba.read_protocol(state, jnp.array([3], jnp.int32),
                                strategy=strategy)
    return np.asarray(vals)[0], bool(np.asarray(ok)[0]), old, np.asarray(new)


def test_torn_seqlock_blocks_reader():
    val, ok, old, new = _torn("seqlock")
    assert not ok  # reader detects the in-flight write and must retry/block


def test_torn_simplock_blocks_reader():
    val, ok, old, new = _torn("simplock")
    assert not ok


def test_torn_indirect_reader_sees_old_value():
    # Pointer not yet swung: the linearization point has not happened.
    val, ok, old, new = _torn("indirect")
    assert ok
    np.testing.assert_array_equal(val, old)


@pytest.mark.parametrize("strategy", ["cached_wf", "cached_me"])
def test_torn_cached_reader_recovers_new_value(strategy):
    # Backup installed = linearization point passed: readers get the NEW
    # value from the backup without waiting for the cache copy to finish.
    val, ok, old, new = _torn(strategy)
    assert ok
    np.testing.assert_array_equal(val, new)


def test_torn_plain_corrupts():
    # Negative control: without a protocol the reader sees a half-write.
    val, ok, old, new = _torn("plain")
    assert ok
    assert not (np.array_equal(val, old) or np.array_equal(val, new))
    np.testing.assert_array_equal(val[:3], new[:3])   # torn prefix
    np.testing.assert_array_equal(val[3:], old[3:])   # stale suffix


# ---------------------------------------------------------------------------
# Traffic model sanity: the paper's cache-locality ordering.
# ---------------------------------------------------------------------------

def test_indirect_costs_two_dependent_chains_on_loads():
    tab, initial, rng = _mk("indirect")
    ops = sem.make_op_batch(np.full(16, sem.LOAD),
                            rng.integers(0, 16, 16), k=4)
    _, _, traffic = tab.apply(ops)
    assert int(traffic.dep_chains) == 2


@pytest.mark.parametrize("strategy", ["seqlock", "cached_wf", "cached_me"])
def test_fast_path_single_chain_on_uncontended_loads(strategy):
    tab, initial, rng = _mk(strategy)
    ops = sem.make_op_batch(np.full(16, sem.LOAD),
                            rng.integers(0, 16, 16), k=4)
    _, _, traffic = tab.apply(ops)
    assert int(traffic.dep_chains) == 1


def test_cached_me_reads_cheaper_than_indirect():
    rng = np.random.default_rng(0)
    slots = rng.integers(0, 64, 128)
    ops = sem.make_op_batch(np.full(128, sem.LOAD), slots, k=8)
    bytes_read = {}
    for s in ("cached_me", "indirect"):
        tab, _, _ = _mk(s, n=64, k=8, p_max=256)
        _, _, tr = tab.apply(ops)
        bytes_read[s] = float(tr.bytes_read)
    # indirect reads ptr+node; cached reads cell+2 meta words. Same order,
    # but indirect pays the dependent chain; bytes are close — the chain
    # count (above) is the differentiator, bytes must not be *lower* for
    # indirect than the pure cell payload.
    assert bytes_read["indirect"] >= 128 * (8 * 4)


# ---------------------------------------------------------------------------
# Table 1 space accounting.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", PROTOCOLS)
def test_memory_accounting_matches_layout(strategy):
    n, k, p = 32, 4, 16
    state = ba.init(n, k, ba.Strategy(strategy), p)
    actual = ba.state_nbytes(state)
    predicted = ba.memory_bytes(n, k, p, ba.Strategy(strategy))
    # predicted counts layout fields; the pytree also carries scalars and,
    # for INDIRECT, the engine shadow (documented simulation artifact).
    slack = 2 * 4 + 4  # ring_head, alloc_gen scalars
    if strategy == "indirect":
        slack += n * k * 4  # engine shadow array (not part of the layout)
    if strategy in ("seqlock", "plain", "simplock"):
        slack += 4 * 2
    assert abs(actual - predicted) <= slack + n * 4, (actual, predicted)


def test_cached_me_space_independent_of_n_beyond_table():
    # The pool is O(p), NOT O(n): the paper's memory-efficiency claim.
    k, p = 8, 64
    small = ba.memory_bytes(1_000, k, p, ba.Strategy.CACHED_ME)
    big = ba.memory_bytes(100_000, k, p, ba.Strategy.CACHED_ME)
    pool_small = small - 1_000 * (k + 2) * 4
    pool_big = big - 100_000 * (k + 2) * 4
    assert pool_small == pool_big


def test_cached_wf_uses_twice_the_node_space_of_cached_me():
    n, k, p = 10_000, 8, 32
    wf = ba.memory_bytes(n, k, p, ba.Strategy.CACHED_WF)
    me = ba.memory_bytes(n, k, p, ba.Strategy.CACHED_ME)
    assert wf > me + n * k * 4 * 0.9  # ~nk extra: the always-populated backups


# ---------------------------------------------------------------------------
# Reclamation ring: retired nodes are not immediately reused (SMR analogue).
# ---------------------------------------------------------------------------

def test_ring_reclamation_delay():
    n, k, p = 8, 2, 4
    tab, initial, rng = _mk("indirect", n=n, k=k, p_max=p)
    before = np.asarray(tab.state.bptr).copy()
    ops = sem.make_op_batch(np.full(4, sem.STORE), np.arange(4),
                            desired=rng.integers(0, 2**32, (4, 2), np.uint32),
                            k=2)
    tab.apply(ops)
    after = np.asarray(tab.state.bptr)
    # Updated cells got FRESH nodes (no immediate reuse of their old ones).
    assert not np.any(np.isin(after[:4], before[:4]))
    # Old nodes are back in the ring for eventual reuse.
    ring = np.asarray(tab.state.free_ring)
    assert all(b in ring for b in before[:4])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(LOCKFREE),
       steps=st.integers(1, 4))
def test_property_multi_step_consistency(seed, strategy, steps):
    rng = np.random.default_rng(seed)
    n, k, p = 12, 3, 20
    initial = rng.integers(0, 2**32, size=(n, k), dtype=np.uint32)
    tab = ba.BigAtomicTable(n, k, strategy, 64, initial)
    ref_data, ref_ver = initial.copy(), np.zeros(n, np.uint32)
    for _ in range(steps):
        ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=0.7,
                               zipf=1.3, current=ref_data)
        ref_data, ref_ver, _ = sem.apply_batch_reference(ref_data, ref_ver, ops)
        tab.apply(ops)
    np.testing.assert_array_equal(np.asarray(tab.logical()), ref_data)
    vals, ok = ba.read_protocol(tab.state, jnp.arange(n, dtype=jnp.int32),
                                strategy=strategy)
    assert bool(jnp.all(ok))
    np.testing.assert_array_equal(np.asarray(vals), ref_data)
