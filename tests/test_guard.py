"""repro.guard acceptance suite (ISSUE 10).

The contracts, all tier-1:

  * DETECTION IS TOTAL — every boundary-injected bit flip / torn write is
    detected by the scrub pass (digest chain bijectivity + structural
    invariants), 100% across strategies, fields, words and bits.
  * CHAOS IS SURVIVABLE — >= 50 seeded schedules x 4 strategies of mixed
    scheduling + data-plane faults: every injection is repaired or
    quarantined, the oracle replay of the surviving history bit-agrees on
    every delivered result and every non-quarantined cell, and zero
    corruptions go undetected.
  * DEGRADATION IS GRACEFUL — streams whose cells are all quarantined
    retry through a backoff budget and shed with a recorded reason while
    the rest of the run completes; serving submit() sheds with a typed
    verdict under sustained overload.
  * CHECKPOINTS SELF-VERIFY — per-leaf CRCs round-trip every dtype
    (bf16/uint32 included), and restore falls back to the newest
    VERIFYING step past corrupt or truncated damage.
  * OFF IS FREE — BIGATOMIC_GUARD unset/off builds no scrubber and adds
    ZERO new traces to the engine round across an executor run.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from oracle import replay_executor_history
from repro import guard
from repro.analysis import tracing
from repro.core import engine
from repro.core.specs import AtomicSpec, VersionSpec
from repro.guard.chaos import CHAOS_STRATEGIES, run_chaos, verify_chaos
from repro.guard.inject import inject_table_fault
from repro.guard.scrub import ScrubReport, Scrubber, digest_np
from repro.guard.scrub import scrub as scrub_pass
from repro.guard.scrub import _cell_digest
from repro.runtime.executor import Executor, LocalTarget
from repro.runtime.faults import DATA_KINDS, Fault, FaultInjector
from repro.runtime.streams import SyntheticStream
from repro.sync.queue import BackoffPolicy

STRATEGIES = CHAOS_STRATEGIES
CHAOS_SEEDS = int(os.environ.get("BIGATOMIC_CHAOS_SEEDS", "50"))


def _random_state(spec, seed):
    rng = np.random.default_rng(seed)
    init = rng.integers(0, 2 ** 32, (spec.n, spec.k), dtype=np.uint32)
    return engine.init(spec, init)


# ---------------------------------------------------------------------------
# Detection is total.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_bit_flip_and_torn_write_detection_is_100pct(strategy):
    """Sweep random single-cell corruptions: EVERY one lands in the scrub
    report's detected set — the digest chain makes this structural."""
    spec = AtomicSpec(32, 3, strategy, 16)
    for seed in range(30):
        state = _random_state(spec, seed)
        baseline = np.asarray(guard.cell_digest(spec, state))
        rng = np.random.default_rng(1000 + seed)
        kind = "bit_flip" if seed % 2 else "torn_write"
        fault = Fault(round=1, kind=kind)
        corrupt, info = inject_table_fault(spec, state, fault, rng)
        report = scrub_pass(spec, corrupt, baseline=baseline)
        assert info["slot"] in report.detected, (strategy, seed, info)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_clean_state_scrubs_clean(strategy):
    spec = AtomicSpec(32, 3, strategy, 16)
    state = _random_state(spec, 7)
    baseline = np.asarray(guard.cell_digest(spec, state))
    report = scrub_pass(spec, state, baseline=baseline)
    assert report.clean and not report.invariant_violations
    assert guard.violation_mask(spec, state).sum() == 0


def test_invariants_name_the_right_violation():
    """Field-targeted corruption trips the per-strategy invariant the
    design names for it (DESIGN.md §11 table)."""
    rng = np.random.default_rng(0)

    def viols(strategy, **kw):
        spec = AtomicSpec(16, 2, strategy, 8)
        state = _random_state(spec, 3)
        corrupt, _ = inject_table_fault(
            spec, state, Fault(round=1, kind="bit_flip", slot=5, **kw), rng)
        return {name: np.flatnonzero(np.asarray(m)).tolist()
                for name, m in guard.check_invariants(spec, corrupt).items()
                if np.asarray(m).any()}

    # odd version at rest = writer died mid-cell
    assert viols("seqlock", field="version", bit=0) == \
        {"version_parity": [5]}
    # indirect: a flipped high bptr bit leaves [0, pool); shadow disagrees
    v = viols("indirect", field="bptr", bit=20)
    assert "pointer_range" in v and v["pointer_range"] == [5]
    # indirect: a pool flip on the live node breaks the commit shadow
    assert viols("indirect", field="pool", word=0) == \
        {"shadow_agrees": [5]}
    # cached_wf: backup flip breaks cache/backup agreement
    assert viols("cached_wf", field="pool", word=0) == \
        {"cache_matches_backup": [5]}
    # cached_me: bptr damage breaks the tagged-null encoding
    assert viols("cached_me", field="bptr", bit=3) == {"tagged_null": [5]}


def test_version_list_invariants():
    import repro.txn.versionlist as vl
    vspec = VersionSpec(8, 2, 4, "seqlock", 8)
    vstate = vl.init(vspec)
    slots = jnp.arange(8, dtype=jnp.int32)
    for ts in range(1, 6):
        vstate = vl.publish(vspec, vstate, slots,
                            jnp.full((8, 2), ts, jnp.uint32),
                            jnp.full((8,), ts, jnp.uint32))
    masks = {k: np.asarray(v) for k, v in
             guard.check_version_list(vspec, vstate).items()}
    assert all(m.sum() == 0 for m in masks.values()), masks
    # corrupt slot 3's head prev word: the ring no longer agrees
    data = np.array(vstate.table.data)
    data[3, vspec.k + 1] ^= 1
    bad = vstate._replace(table=vstate.table._replace(
        data=jnp.asarray(data)))
    got = {k: np.flatnonzero(np.asarray(v)).tolist() for k, v in
           guard.check_version_list(vspec, bad).items()}
    assert got["head_prev_agrees"] == [3]


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_pallas_digest_equals_xla(strategy):
    """The blocked Pallas digest pass computes the XLA twin bit-exactly
    (interpret mode on CPU, per kernels/engine_round resolution)."""
    spec = AtomicSpec(20, 3, strategy, 8)   # 20 forces ragged-tail padding
    state = _random_state(spec, 11)
    a = np.asarray(_cell_digest(spec, state, "xla", True))
    b = np.asarray(_cell_digest(spec, state, "pallas", True))
    np.testing.assert_array_equal(a, b)


def test_numpy_digest_matches_jitted():
    spec = AtomicSpec(16, 2, "seqlock", 8)
    state = _random_state(spec, 5)
    a = np.asarray(guard.cell_digest(spec, state))
    b = digest_np(np.asarray(engine.logical(spec, state)),
                  np.asarray(state.version))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Fault injector: determinism + ordering contract.
# ---------------------------------------------------------------------------

def test_injector_determinism_same_seed_same_draws():
    """Two runs of one schedule under one seed realize IDENTICAL victim
    choices and final table bits (the documented per-fault rng contract)."""
    outs = []
    for _ in range(2):
        res = run_chaos(21, "indirect", data_faults=4)
        ex = res["executor"]
        outs.append((
            [info for _r, _f, info in ex.data_faults],
            np.asarray(engine.logical(res["spec"], ex.target.state)),
            ex.scrubber.poison.copy()))
    assert outs[0][0] == outs[1][0]
    np.testing.assert_array_equal(outs[0][1], outs[1][1])
    np.testing.assert_array_equal(outs[0][2], outs[1][2])


def test_injector_seed_changes_draws():
    a = FaultInjector([Fault(round=1, kind="bit_flip")], seed=1)
    b = FaultInjector([Fault(round=1, kind="bit_flip")], seed=2)
    assert a.rng(0).integers(2 ** 31) != b.rng(0).integers(2 ** 31)


def test_injector_ordering_contract():
    """Scheduling faults honor (round, after_issues); data faults defer
    to the boundary poll regardless of after_issues; both fire once."""
    faults = [Fault(round=2, kind="bit_flip"),
              Fault(round=1, kind="delay", stream=0, after_issues=2),
              Fault(round=1, kind="torn_write")]
    inj = FaultInjector(faults, seed=0)
    assert inj.poll(1, 0) == []                      # before after_issues
    assert [f.kind for f in inj.poll(1, 2)] == ["delay"]
    # boundary of round 1: only the round-1 data fault, original order
    due = inj.poll_boundary(1)
    assert [f.kind for f, _rng in due] == ["torn_write"]
    due = inj.poll_boundary(2)
    assert [f.kind for f, _rng in due] == ["bit_flip"]
    assert inj.exhausted and len(inj.fired) == 3


# ---------------------------------------------------------------------------
# Chaos: zero undetected corruptions.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_chaos_zero_undetected_corruptions(strategy):
    """>= 50 seeded schedules per strategy: every injected fault detected
    AND repaired-or-quarantined, oracle replay bit-agrees on every
    delivered result and every non-quarantined cell."""
    injected = 0
    for seed in range(CHAOS_SEEDS):
        res = run_chaos(seed, strategy,
                        data_faults=2 + seed % 3,
                        sched_faults=seed % 2,
                        n_batches=3 + seed % 2, width=5)
        verdict = verify_chaos(res)
        assert verdict["ok"], (strategy, seed, verdict)
        injected += verdict["injected_data_faults"]
    assert injected >= CHAOS_SEEDS          # schedules actually bit


def test_chaos_with_checkpoint_damage(tmp_path):
    """ckpt_corrupt / ckpt_truncate in the schedule: the run survives and
    restore_latest still finds a verifying step afterwards."""
    from repro.checkpoint import disk
    res = run_chaos(5, "seqlock", ckpt_faults=2, data_faults=1,
                    checkpoint_dir=str(tmp_path))
    assert verify_chaos(res)["ok"]
    damaged = [info for _r, f, info in res["executor"].data_faults
               if f.kind in ("ckpt_corrupt", "ckpt_truncate")]
    assert damaged, "schedule should have hit a checkpoint leaf"
    template = res["executor"]._ck_payload()
    _state, meta, step = disk.restore_latest(str(tmp_path), template)
    assert not disk.verify_checkpoint(str(tmp_path), damaged[0]["step"]) \
        or step >= damaged[0]["step"]


# ---------------------------------------------------------------------------
# Graceful degradation: poison contract, retry budget, stream shedding.
# ---------------------------------------------------------------------------

def test_poisoned_cells_fail_ops_and_streams_shed(monkeypatch):
    """Quarantine the slot range four confined streams hammer: their ops
    come back success=False (lanes IDLE-rewritten, oracle agrees), they
    burn their retry budgets, shed with a recorded reason — and a fifth
    healthy stream still completes."""
    monkeypatch.setenv("BIGATOMIC_GUARD", "on")
    n, k, width = 16, 2, 4
    spec = AtomicSpec(n, k, "seqlock", 16)
    victims = [SyntheticStream(f"s{i}", seed=500 + i, n=n, k=k, width=width,
                               n_batches=8, slot_lo=0, slot_hi=4)
               for i in range(4)]
    healthy = SyntheticStream("healthy", seed=555, n=n, k=k, width=width,
                              n_batches=8, slot_lo=4)
    faults = [Fault(round=2, kind="bit_flip", slot=s, field="data")
              for s in range(4)]
    ex = Executor(LocalTarget(spec), victims + [healthy],
                  injector=FaultInjector(faults, seed=3),
                  checkpoint_every=0,   # only the round-0 baseline: every
                  retry_budget=1,       # written cell stays dirty =>
                  backoff=BackoffPolicy("none"))             # quarantine
    rep = ex.run()

    assert rep["poisoned"] == 4
    assert sorted(s["stream"] for s in rep["shed"]) == [0, 1, 2, 3]
    assert rep["shed"][0]["reason"] == "all lanes target quarantined cells"
    assert healthy.done() and not victims[0].done()
    # the poison contract, end to end: post-quarantine victim batches
    # delivered all-False success over fully-IDLE journaled ops
    quarantine_round = min(r.round for r in ex.scrubber.reports
                           if r.quarantined)
    assert quarantine_round >= 2
    post = [r for r in ex.history if r.stream == 0
            and np.asarray(r.ops.kind == engine.IDLE).all()]
    assert post, "expected fully-masked victim batches after quarantine"
    assert all(not r.success.any() for r in post)
    # the surviving history replays bit-exactly through the oracle
    replay_executor_history(n, k, [width] * 5, ex.history, check=True)
    assert rep["events"]["exec.shed"] == 4


def test_issue_exception_retries_then_sheds(monkeypatch):
    """A target whose issue keeps raising: the stream rolls back, backs
    off, and sheds after the budget instead of crashing the run."""
    monkeypatch.delenv("BIGATOMIC_GUARD", raising=False)
    spec = AtomicSpec(8, 2, "seqlock", 8)
    target = LocalTarget(spec)
    boom = {"left": 100}

    real_issue = target.issue

    def flaky_issue(ops, ctx, *, donate=True):
        if boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("injected issue failure")
        return real_issue(ops, ctx, donate=donate)

    target.issue = flaky_issue
    s = SyntheticStream("s0", seed=1, n=8, k=2, width=4, n_batches=3)
    ex = Executor(target, [s], retry_budget=2,
                  backoff=BackoffPolicy("none"))
    rep = ex.run()
    assert rep["shed"] and rep["shed"][0]["reason"] == "issue raised"
    assert rep["shed"][0]["attempts"] == 3 and not s.done()


def test_serving_overload_sheds_typed(monkeypatch):
    """submit() under sustained saturation returns a typed Shed verdict;
    without a policy the legacy full-ring RuntimeError is preserved."""
    import jax

    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serving import (Admitted, OverloadPolicy, Request,
                               ServingEngine, Shed)

    cfg = dataclasses.replace(get_config("deepseek_7b", reduced=True),
                              param_dtype="float32",
                              compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def req(rid):
        return Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, 4).astype(np.int32), max_new_tokens=64)

    eng = ServingEngine(cfg, params, max_batch=2, n_pages=32, page_size=8,
                        max_queue=4,
                        overload=OverloadPolicy(watermark=0.5, patience=1))
    assert isinstance(eng.submit(req(0)), Admitted)
    assert isinstance(eng.submit(req(1)), Admitted)
    eng.step()                      # both prefill: no free decode slot
    verdicts = [eng.submit(req(2 + i)) for i in range(6)]
    sheds = [v for v in verdicts if isinstance(v, Shed)]
    assert sheds, verdicts
    assert sheds[0].reason in ("sustained overload",
                               "admission queue full")
    assert sheds[0].free_slots == 0 and sheds[0].queue_depth >= 2
    assert eng.shed_count == len(sheds)
    # a shed rid is NOT parked in the registry
    assert all(v.rid not in eng.requests for v in sheds)

    legacy = ServingEngine(cfg, params, max_batch=2, n_pages=32,
                           page_size=8, max_queue=2)
    for rid in range(legacy.admit_q.capacity):
        legacy.submit(req(rid))
    with pytest.raises(RuntimeError, match="admission queue full"):
        legacy.submit(req(99))


# ---------------------------------------------------------------------------
# Checkpoint hardening.
# ---------------------------------------------------------------------------

def test_checkpoint_crc_roundtrip_all_dtypes(tmp_path):
    import ml_dtypes

    from repro.checkpoint import disk
    state = {
        "f32": np.linspace(0, 1, 12, dtype=np.float32).reshape(3, 4),
        "u32": np.arange(8, dtype=np.uint32),
        "bf16": np.arange(6, dtype=np.float32).astype(ml_dtypes.bfloat16),
        "b": np.array([True, False]),
    }
    disk.save_checkpoint(str(tmp_path), 3, state)
    assert disk.verify_checkpoint(str(tmp_path), 3)
    back, _meta = disk.restore_checkpoint(str(tmp_path), 3, state,
                                          verify=True)
    for key, want in state.items():
        got = np.asarray(back[key])
        assert got.dtype == want.dtype, key
        np.testing.assert_array_equal(
            got.view(np.uint8), np.asarray(want).view(np.uint8), err_msg=key)


def test_restore_latest_falls_back_past_damage(tmp_path):
    from repro.checkpoint import disk
    state = {"x": np.arange(16, dtype=np.uint32)}
    disk.save_checkpoint(str(tmp_path), 1, state)
    good = {"x": np.arange(16, dtype=np.uint32) + 100}
    disk.save_checkpoint(str(tmp_path), 2, good)
    bad = {"x": np.arange(16, dtype=np.uint32) + 200}
    disk.save_checkpoint(str(tmp_path), 3, bad)

    # corrupt step 3 (flip one payload byte), truncate step 2's leaf
    leaf3 = tmp_path / "step_00000003" / "x.npy"
    raw = bytearray(leaf3.read_bytes())
    raw[-1] ^= 0xFF
    leaf3.write_bytes(bytes(raw))
    leaf2 = tmp_path / "step_00000002" / "x.npy"
    leaf2.write_bytes(leaf2.read_bytes()[: leaf2.stat().st_size // 2])

    assert not disk.verify_checkpoint(str(tmp_path), 3)
    assert not disk.verify_checkpoint(str(tmp_path), 2)
    assert disk.verify_checkpoint(str(tmp_path), 1)
    restored, _meta, step = disk.restore_latest(str(tmp_path), state)
    assert step == 1
    np.testing.assert_array_equal(restored["x"], state["x"])
    with pytest.raises(disk.CheckpointError):
        disk.restore_checkpoint(str(tmp_path), 3, state, verify=True)


def test_restore_latest_no_verifying_step(tmp_path):
    from repro.checkpoint import disk
    state = {"x": np.arange(4, dtype=np.uint32)}
    with pytest.raises(FileNotFoundError):
        disk.restore_latest(str(tmp_path), state)
    disk.save_checkpoint(str(tmp_path), 1, state)
    leaf = tmp_path / "step_00000001" / "x.npy"
    leaf.write_bytes(b"")
    with pytest.raises(disk.CheckpointError):
        disk.restore_latest(str(tmp_path), state)


def test_executor_resume_skips_damaged_newest(monkeypatch, tmp_path):
    """End to end: damage the newest disk checkpoint after a run; a fresh
    executor resumes from the older VERIFYING step and finishes with the
    table bit-identical to the uninterrupted run."""
    monkeypatch.delenv("BIGATOMIC_GUARD", raising=False)
    n, k, width = 16, 2, 4

    def mk(ckdir=None):
        spec = AtomicSpec(n, k, "seqlock", 16)
        streams = [SyntheticStream("s0", seed=77, n=n, k=k, width=width,
                                   n_batches=6)]
        return Executor(LocalTarget(spec), streams, checkpoint_dir=ckdir,
                        checkpoint_every=2)

    ex1 = mk(str(tmp_path))
    ex1.run()
    want = ex1.target.snapshot()

    from repro.checkpoint import disk
    steps = disk.list_steps(str(tmp_path))
    assert len(steps) >= 2
    newest = tmp_path / f"step_{steps[-1]:08d}"
    victim = sorted(newest.glob("*.npy"))[0]
    victim.write_bytes(victim.read_bytes()[:8])
    assert not disk.verify_checkpoint(str(tmp_path), steps[-1])

    ex2 = mk()                          # no ckpt dir: don't re-save steps
    resumed_round = ex2.resume(str(tmp_path))
    assert resumed_round == steps[-2]
    ex2.run()
    got = ex2.target.snapshot()
    np.testing.assert_array_equal(got["logical"], want["logical"])
    np.testing.assert_array_equal(got["versions"], want["versions"])


# ---------------------------------------------------------------------------
# Off is free.
# ---------------------------------------------------------------------------

def _run_once(seed):
    spec = AtomicSpec(16, 2, "cached_me", 16)
    streams = [SyntheticStream(f"s{i}", seed=seed + i, n=16, k=2, width=4,
                               n_batches=3) for i in range(2)]
    ex = Executor(LocalTarget(spec), streams)
    rep = ex.run()
    return ex, rep


def test_guard_off_is_free(monkeypatch):
    """BIGATOMIC_GUARD unset: no scrubber exists, no scrub/shed state is
    recorded, and a full executor run adds ZERO new traces to the engine
    round — the issue path is byte-identical to the unguarded build."""
    monkeypatch.delenv("BIGATOMIC_GUARD", raising=False)
    ex, _rep = _run_once(800)                 # warm every signature
    assert ex.scrubber is None
    with tracing.assert_max_new_traces(engine._apply, 0):
        ex, rep = _run_once(900)
    assert ex.scrubber is None
    assert rep["scrubs"] == [] and rep["poisoned"] == 0
    assert "exec.scrubs" not in rep["events"]


def test_guard_env_validation(monkeypatch):
    monkeypatch.setenv("BIGATOMIC_GUARD", "sideways")
    with pytest.raises(ValueError, match="BIGATOMIC_GUARD"):
        guard.configured()
    monkeypatch.setenv("BIGATOMIC_GUARD", "on")
    assert guard.enabled()


# ---------------------------------------------------------------------------
# Satellites: compare.py suite handling, scrub report JSON.
# ---------------------------------------------------------------------------

def test_compare_missing_suite_warns_not_fails(capsys):
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks import compare as cmp

    old = {"schema": 1, "suites": {
        "atomics": [{"name": "atomics/x", "ops_s": 100.0}],
        "faults": [{"name": "faults/scrub/seqlock", "ops_s": 50.0}]}}
    new_missing_suite = {"schema": 1, "suites": {
        "atomics": [{"name": "atomics/x", "ops_s": 101.0}]}}
    rows = list(cmp.compare(old, new_missing_suite, 0.10))
    verdicts = {name: v for name, _m, _o, _n, _d, v in rows}
    assert verdicts["faults/scrub/seqlock"] == "MISSING-SUITE"
    # a row missing WITHIN a surviving suite is still a hard regression
    new_missing_row = {"schema": 1, "suites": {
        "atomics": [], "faults": old["suites"]["faults"]}}
    rows = list(cmp.compare(old, new_missing_row, 0.10))
    assert ("atomics/x", "-", None, None, None, "MISSING") in rows


def test_scrub_report_round_trips_json():
    res = run_chaos(2, "seqlock")
    import json
    for rep in res["executor"].scrubber.reports:
        doc = json.loads(json.dumps(rep.to_json()))
        assert doc["clean"] == rep.clean
        assert doc["n"] == rep.n and doc["strategy"] == "seqlock"
