"""Test bootstrap: put src/ on the path and, when the real `hypothesis`
package is absent from the image, install the deterministic fallback shim so
the property tests still collect and run (see repro/_compat/hypothesis_shim)."""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_shim

    sys.modules["hypothesis"] = hypothesis_shim
    sys.modules["hypothesis.strategies"] = hypothesis_shim.strategies
